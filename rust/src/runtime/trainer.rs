//! End-to-end training driver: runs the fused `tinycnn_train_step`
//! artifact in a loop from Rust — the proof that L1 (Pallas kernels)
//! -> L2 (JAX graph) -> AOT -> L3 (this coordinator) compose, with
//! Python nowhere on the path.

use anyhow::{bail, Result};

use crate::util::rng::Rng;

use super::artifact::{DType, TensorSpec};
use super::engine::{Engine, HostTensor, LoadedWorkload};

/// Synthetic classification data with learnable structure (mirrors
/// python/tests/test_model.py): class-k images carry a brightness
/// stamp (k+1)/10 in their top-left 4x4 corner over N(0, 0.1) noise.
pub struct SyntheticData {
    pub img: usize,
    pub classes: usize,
    rng: Rng,
}

impl SyntheticData {
    pub fn new(img: usize, classes: usize, seed: u64) -> Self {
        SyntheticData { img, classes, rng: Rng::new(seed) }
    }

    /// One batch: (images NHWC f32, labels i32).
    pub fn batch(&mut self, n: usize) -> (Vec<f32>, Vec<i32>) {
        let (h, w, c) = (self.img, self.img, 3usize);
        let mut xs = vec![0f32; n * h * w * c];
        let mut ys = vec![0i32; n];
        for i in 0..n {
            let label = self.rng.below(self.classes as u64) as i32;
            ys[i] = label;
            let stamp = (label as f32 + 1.0) / 10.0;
            for yy in 0..h {
                for xx in 0..w {
                    for ch in 0..c {
                        let idx = ((i * h + yy) * w + xx) * c + ch;
                        let mut v = 0.1 * self.rng.normal() as f32;
                        if yy < 4 && xx < 4 {
                            v += stamp;
                        }
                        xs[idx] = v;
                    }
                }
            }
        }
        (xs, ys)
    }
}

/// He-normal initialization for the parameter tensors declared by the
/// manifest (weights: fan_in from the shape; 1-D tensors = biases = 0).
pub fn init_params(specs: &[TensorSpec], seed: u64) -> Result<Vec<HostTensor>> {
    let mut rng = Rng::new(seed);
    specs
        .iter()
        .map(|s| {
            if s.dtype != DType::F32 {
                bail!("non-f32 parameter tensor: {:?}", s);
            }
            if s.shape.len() <= 1 {
                return Ok(HostTensor::F32(vec![0.0; s.elems()]));
            }
            let fan_in: usize =
                s.shape[..s.shape.len() - 1].iter().product();
            let std = (2.0 / fan_in as f64).sqrt();
            Ok(HostTensor::F32(
                (0..s.elems())
                    .map(|_| (rng.normal() * std) as f32)
                    .collect(),
            ))
        })
        .collect()
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub steps: usize,
    pub batch: usize,
    /// Wall-clock seconds for the stepping loop (compile excluded).
    pub seconds: f64,
}

impl TrainReport {
    pub fn first_loss(&self) -> f32 {
        *self.losses.first().unwrap_or(&f32::NAN)
    }

    pub fn last_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }

    pub fn steps_per_sec(&self) -> f64 {
        self.steps as f64 / self.seconds.max(1e-9)
    }
}

/// Train the TinyCNN artifact for `steps` steps at learning rate `lr`,
/// threading the updated parameters back each iteration (the artifact
/// is one fused fwd+bwd+SGD HLO module).
pub fn train(
    engine: &Engine,
    steps: usize,
    lr: f32,
    seed: u64,
    mut on_step: impl FnMut(usize, f32),
) -> Result<(TrainReport, Vec<HostTensor>)> {
    let wl: LoadedWorkload = engine.load("tinycnn_train_step")?;
    let n_params = wl.spec.n_params;
    let batch = wl.spec.batch;
    let img = wl.spec.inputs[n_params].shape[1];

    let mut params = init_params(&wl.spec.inputs[..n_params], seed)?;
    let mut data = SyntheticData::new(img, 10, seed ^ 0xDA7A);

    let mut losses = Vec::with_capacity(steps);
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let (xs, ys) = data.batch(batch);
        let mut inputs = params.clone();
        inputs.push(HostTensor::F32(xs));
        inputs.push(HostTensor::I32(ys));
        inputs.push(HostTensor::F32(vec![lr]));
        let mut out = wl.run(&inputs)?;
        let loss = out.remove(0).scalar_f32()?;
        losses.push(loss);
        params = out; // new params come back in manifest order
        on_step(step, loss);
    }
    let report = TrainReport {
        losses,
        steps,
        batch,
        seconds: t0.elapsed().as_secs_f64(),
    };
    Ok((report, params))
}

/// Run the TinyCNN inference artifact on a fresh batch and return
/// top-1 accuracy — used by the e2e example to sanity-check training.
pub fn eval_accuracy(
    engine: &Engine,
    params: &[HostTensor],
    seed: u64,
) -> Result<f32> {
    let wl = engine.load("tinycnn_infer")?;
    let n_params = wl.spec.n_params;
    let batch = wl.spec.batch;
    let img = wl.spec.inputs[n_params].shape[1];
    let mut data = SyntheticData::new(img, 10, seed);
    let (xs, ys) = data.batch(batch);
    let mut inputs = params.to_vec();
    inputs.push(HostTensor::F32(xs));
    let out = wl.run(&inputs)?;
    let logits = out[0].as_f32()?;
    let classes = wl.spec.outputs[0].shape[1];
    let mut correct = 0;
    for i in 0..batch {
        let row = &logits[i * classes..(i + 1) * classes];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred as i32 == ys[i] {
            correct += 1;
        }
    }
    Ok(correct as f32 / batch as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_data_is_class_stamped() {
        let mut d = SyntheticData::new(16, 10, 7);
        let (xs, ys) = d.batch(64);
        assert_eq!(xs.len(), 64 * 16 * 16 * 3);
        assert_eq!(ys.len(), 64);
        // corner mean must track the label
        for i in 0..64 {
            let mut corner = 0.0f32;
            for yy in 0..4 {
                for xx in 0..4 {
                    for c in 0..3 {
                        corner += xs[((i * 16 + yy) * 16 + xx) * 3 + c];
                    }
                }
            }
            let mean = corner / 48.0;
            let expect = (ys[i] as f32 + 1.0) / 10.0;
            assert!(
                (mean - expect).abs() < 0.15,
                "label {} corner mean {mean}",
                ys[i]
            );
        }
    }

    #[test]
    fn he_init_statistics() {
        let specs = vec![
            TensorSpec { shape: vec![512, 64], dtype: DType::F32 },
            TensorSpec { shape: vec![64], dtype: DType::F32 },
        ];
        let p = init_params(&specs, 3).unwrap();
        let w = p[0].as_f32().unwrap();
        let mean: f32 = w.iter().sum::<f32>() / w.len() as f32;
        let var: f32 =
            w.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / w.len() as f32;
        let want = 2.0 / 512.0;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - want).abs() / want < 0.15, "var {var} want {want}");
        assert!(p[1].as_f32().unwrap().iter().all(|&b| b == 0.0));
    }

    #[test]
    fn init_rejects_int_params() {
        let specs = vec![TensorSpec { shape: vec![4], dtype: DType::I32 }];
        assert!(init_params(&specs, 0).is_err());
    }
}
