//! PJRT runtime: loads the AOT-compiled JAX/Pallas workloads
//! (`artifacts/*.hlo.txt`) and executes them from Rust.
//!
//! Python never runs here — `make artifacts` lowers the L2 graphs once
//! (HLO *text*, not serialized protos: the image's xla_extension 0.5.1
//! rejects jax>=0.5's 64-bit instruction ids, while the text parser
//! reassigns ids and round-trips cleanly).
//!
//! * [`artifact`] — `manifest.json` schema: argument/result shapes per
//!   artifact so buffers can be allocated without re-parsing HLO.
//! * [`engine`] — `PjRtClient::cpu()` -> `HloModuleProto::from_text_file`
//!   -> `compile` -> `execute`, with shape-checked literal helpers.
//! * [`trainer`] — the end-to-end training driver used by
//!   `examples/e2e_train.rs`: synthetic data, He init, fused-SGD-step
//!   execution loop with loss tracking.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod trainer;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
#[cfg(feature = "pjrt")]
pub use engine::{Engine, LoadedWorkload};
