//! PJRT execution engine: compile HLO-text artifacts once, execute them
//! many times with shape-checked inputs.

use anyhow::{bail, Context, Result};

use super::artifact::{ArtifactSpec, DType, Manifest, TensorSpec};

/// Host-side tensor (the runtime's exchange format).
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, got {} elems", v.len());
        }
        Ok(v[0])
    }

    fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(_) => DType::F32,
            HostTensor::I32(_) => DType::I32,
        }
    }

    fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        if self.dtype() != spec.dtype {
            bail!("dtype mismatch: host {:?} vs spec {:?}", self.dtype(), spec.dtype);
        }
        if self.len() != spec.elems() {
            bail!(
                "element-count mismatch: host {} vs spec {:?} ({})",
                self.len(),
                spec.shape,
                spec.elems()
            );
        }
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(v) => xla::Literal::vec1(v),
            HostTensor::I32(v) => xla::Literal::vec1(v),
        };
        if spec.shape.is_empty() {
            // rank-0: reshape a 1-element vec to scalar
            Ok(lit.reshape(&[])?)
        } else {
            Ok(lit.reshape(&dims)?)
        }
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
        Ok(match spec.dtype {
            DType::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
            DType::I32 => HostTensor::I32(lit.to_vec::<i32>()?),
        })
    }
}

/// A compiled workload: the PJRT executable plus its manifest contract.
pub struct LoadedWorkload {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedWorkload {
    /// Execute with shape-checked host tensors; returns outputs in
    /// manifest order (aot.py lowers with return_tuple=True, so the
    /// root is always a tuple).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&self.spec.inputs)
            .enumerate()
            .map(|(i, (t, s))| {
                t.to_literal(s).with_context(|| {
                    format!("{} input #{i}", self.spec.name)
                })
            })
            .collect::<Result<_>>()?;

        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: runtime returned {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(l, s)| HostTensor::from_literal(l, s))
            .collect()
    }
}

/// The engine owns the PJRT client and loads workloads from a manifest.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
}

impl Engine {
    /// CPU-PJRT engine over the given artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            manifest: Manifest::load(artifacts_dir)?,
        })
    }

    /// Default artifacts location (repo `artifacts/`).
    pub fn default() -> Result<Engine> {
        Self::new(Manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact.
    pub fn load(&self, name: &str) -> Result<LoadedWorkload> {
        let spec = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        Ok(LoadedWorkload { spec, exe })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checks() {
        let spec = TensorSpec { shape: vec![2, 2], dtype: DType::F32 };
        let ok = HostTensor::F32(vec![1.0; 4]);
        assert!(ok.to_literal(&spec).is_ok());
        let wrong_len = HostTensor::F32(vec![1.0; 3]);
        assert!(wrong_len.to_literal(&spec).is_err());
        let wrong_ty = HostTensor::I32(vec![1; 4]);
        assert!(wrong_ty.to_literal(&spec).is_err());
    }

    #[test]
    fn scalar_helpers() {
        let t = HostTensor::F32(vec![2.5]);
        assert_eq!(t.scalar_f32().unwrap(), 2.5);
        assert!(HostTensor::F32(vec![1.0, 2.0]).scalar_f32().is_err());
        assert!(HostTensor::I32(vec![1]).scalar_f32().is_err());
        assert!(!t.is_empty());
    }
}
