//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! and the Rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Element type of a tensor argument/result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype in manifest: {other}"),
        }
    }
}

/// Shape + dtype of one argument or result.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(
            j.get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("tensor spec missing dtype"))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One compiled workload.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// "gemm" | "infer" | "train_step".
    pub kind: String,
    /// Parameter-tensor count for model workloads (params come first in
    /// the argument list, by the aot.py convention).
    pub n_params: usize,
    pub batch: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let root = json::parse(&text).context("parsing manifest.json")?;
        let obj = root
            .as_obj()
            .ok_or_else(|| anyhow!("manifest root must be an object"))?;
        let mut artifacts = BTreeMap::new();
        for (name, j) in obj {
            let tensors = |key: &str| -> Result<Vec<TensorSpec>> {
                j.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("{name}: missing {key}"))?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: j
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("{name}: missing file"))?
                        .to_string(),
                    inputs: tensors("inputs")?,
                    outputs: tensors("outputs")?,
                    kind: j
                        .get("kind")
                        .and_then(Json::as_str)
                        .unwrap_or("unknown")
                        .to_string(),
                    n_params: j
                        .get("n_params")
                        .and_then(Json::as_usize)
                        .unwrap_or(0),
                    batch: j.get("batch").and_then(Json::as_usize).unwrap_or(0),
                },
            );
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Default artifacts directory (repo-root `artifacts/`).
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, text: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn parses_wellformed_manifest() {
        let dir = std::env::temp_dir().join("deepnvm_manifest_ok");
        write_manifest(
            &dir,
            r#"{ "gemm_128": {
                "file": "gemm_128.hlo.txt", "kind": "gemm",
                "inputs": [{"shape": [128, 128], "dtype": "float32"}],
                "outputs": [{"shape": [128, 128], "dtype": "float32"}],
                "m": 128 } }"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("gemm_128").unwrap();
        assert_eq!(a.inputs[0].shape, vec![128, 128]);
        assert_eq!(a.inputs[0].dtype, DType::F32);
        assert_eq!(a.inputs[0].elems(), 128 * 128);
        assert_eq!(a.kind, "gemm");
        assert!(m.hlo_path(a).ends_with("gemm_128.hlo.txt"));
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let dir = std::env::temp_dir().join("deepnvm_manifest_bad");
        write_manifest(
            &dir,
            r#"{ "x": { "file": "x.hlo.txt",
                "inputs": [{"shape": [1], "dtype": "float64"}],
                "outputs": [] } }"#,
        );
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_dir_is_helpful() {
        let err = Manifest::load("/nonexistent/path/xyz").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn scalar_spec_has_one_elem() {
        let s = TensorSpec { shape: vec![], dtype: DType::F32 };
        assert_eq!(s.elems(), 1);
    }
}
