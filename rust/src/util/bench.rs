//! Micro-benchmark harness (criterion substitute for the offline image).
//!
//! Every target in `benches/` uses [`Bench`]: warmup, calibrated
//! iteration count, outlier-robust statistics, and a one-line report
//! compatible with `cargo bench` output scraping. Not as rigorous as
//! criterion, but deterministic, dependency-free, and honest about
//! variance.
//!
//! The harness is wired into `obs`: every sample [`Bench`] takes is
//! mirrored into a global `bench_<name>` histogram, and [`time_into`] /
//! [`hist_ms`] let bench binaries fill their BENCH JSON timing fields
//! from the exact same histograms `GET /metrics` exposes.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub std_ns: f64,
    /// Optional user-supplied items/iteration for throughput reporting.
    pub items_per_iter: f64,
}

impl Measurement {
    pub fn throughput(&self) -> f64 {
        if self.items_per_iter > 0.0 && self.mean_ns > 0.0 {
            self.items_per_iter / (self.mean_ns * 1e-9)
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        let tp = if self.items_per_iter > 0.0 {
            format!("  {:>12.0} items/s", self.throughput())
        } else {
            String::new()
        };
        format!(
            "bench {:<44} {:>12}/iter (+/- {:>10}) n={}{}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            self.iters,
            tp
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    min_iters: u64,
    max_iters: u64,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // Modest budgets: the suite has ~10 bench binaries and 1 CPU.
        Bench {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(700),
            min_iters: 5,
            max_iters: 1_000_000,
            results: vec![],
        }
    }

    /// Quick mode for CI / tests.
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(10),
            measure: Duration::from_millis(50),
            min_iters: 3,
            max_iters: 10_000,
            results: vec![],
        }
    }

    /// Benchmark `f`, which returns a value that is black-boxed.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        self.run_items(name, 0.0, &mut f)
    }

    /// Benchmark with a throughput denominator (items per call).
    pub fn run_items<T>(
        &mut self,
        name: &str,
        items_per_iter: f64,
        f: &mut impl FnMut() -> T,
    ) -> &Measurement {
        // Warmup + estimate cost of one call.
        let warm_start = Instant::now();
        let mut calls = 0u64;
        while warm_start.elapsed() < self.warmup || calls < 1 {
            black_box(f());
            calls += 1;
        }
        let per_call =
            (warm_start.elapsed().as_nanos() as f64 / calls as f64).max(1.0);

        // Choose a batch size so each sample takes ~1/30 of the budget.
        let sample_target_ns = self.measure.as_nanos() as f64 / 30.0;
        let batch =
            ((sample_target_ns / per_call).ceil() as u64).clamp(1, self.max_iters);

        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.measure || samples.len() < self.min_iters as usize
        {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
            if samples.len() > 10_000 {
                break;
            }
        }

        // Mirror every sample into the global obs histogram for this
        // bench, so the BENCH JSON fields and `/metrics` quantiles are
        // derived from the same data the printed report summarizes.
        let hist = crate::obs::global().histogram(&format!("bench_{name}"));
        for s in &samples {
            hist.record(*s as u64);
        }

        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let std = (samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / samples.len() as f64)
            .sqrt();

        let m = Measurement {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: mean,
            median_ns: median,
            std_ns: std,
            items_per_iter,
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Time one call of `f` into the global histogram `name` and return
/// `f`'s value. Bench binaries use this for one-shot phases (a cold
/// sweep, a prewarm) whose durations should land in the same registry
/// the repeated-sample benches feed.
pub fn time_into<T>(name: &str, f: impl FnOnce() -> T) -> T {
    crate::obs::global().histogram(name).time(f)
}

/// Millisecond summary of one global histogram, ready for BENCH JSON.
/// The mean is exact (sum/count); the quantiles are log2-bucket upper
/// bounds, i.e. conservative within 2x.
#[derive(Clone, Copy, Debug)]
pub struct HistMs {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
}

/// Summarize the global histogram `name`, or `None` when it has no
/// samples (the caller then writes `null` into its BENCH JSON field —
/// an absent measurement must never masquerade as 0 ms).
pub fn hist_ms(name: &str) -> Option<HistMs> {
    let snap = crate::obs::global().histogram(name).snapshot();
    if snap.count == 0 {
        return None;
    }
    Some(HistMs {
        count: snap.count,
        mean_ms: snap.mean() / 1e6,
        p50_ms: snap.quantile(0.5) as f64 / 1e6,
        p90_ms: snap.quantile(0.9) as f64 / 1e6,
        p99_ms: snap.quantile(0.99) as f64 / 1e6,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::quick();
        let m = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.iters >= 3);
    }

    #[test]
    fn throughput_reporting() {
        let mut b = Bench::quick();
        let mut f = || 1u64 + 1;
        let m = b.run_items("add", 1000.0, &mut f).clone();
        assert!(m.throughput() > 0.0);
        assert!(m.report().contains("items/s"));
    }

    #[test]
    fn run_mirrors_samples_into_the_global_histogram() {
        let mut b = Bench::quick();
        b.run("histmirror", || black_box(7u64).wrapping_mul(7));
        let h = hist_ms("bench_histmirror").expect("samples were recorded");
        assert!(h.count >= 3, "quick mode takes at least min_iters samples");
        assert!(h.p50_ms <= h.p90_ms && h.p90_ms <= h.p99_ms);
    }

    #[test]
    fn time_into_records_one_sample_and_returns_the_value() {
        let v = time_into("bench_time_into_test", || 41 + 1);
        assert_eq!(v, 42);
        let h = hist_ms("bench_time_into_test").unwrap();
        assert_eq!(h.count, 1);
        assert!(h.mean_ms >= 0.0);
        assert!(hist_ms("bench_never_recorded").is_none());
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(12.0), "12ns");
        assert_eq!(fmt_ns(1200.0), "1.20us");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
    }
}
