//! Shared infrastructure substrates.
//!
//! The offline vendor set ships only `xla` and `anyhow`, so everything a
//! production framework would normally pull from crates.io is implemented
//! here: a counter-based PRNG ([`rng`]), summary statistics ([`stats`]),
//! a JSON parser/writer ([`json`]) for the AOT manifest and result
//! stores, CSV emission ([`csv`]), paper-style fixed-width tables
//! ([`table`]), a micro-benchmark harness ([`bench`]) used by every
//! `benches/` target, and a property-based testing kit ([`proptest`])
//! used across the device/nvsim/gpusim test suites.

pub mod bench;
pub mod csv;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;
