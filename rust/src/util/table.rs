//! Fixed-width table renderer — prints the paper-style tables the bench
//! harnesses and the CLI report (`deepnvm table2` etc.) emit to stdout.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table with a header row and unicode-free box drawing
/// (terminal- and log-friendly).
#[derive(Debug)]
pub struct Table {
    header: Vec<String>,
    align: Vec<Align>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            align: header
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
            title: None,
        }
    }

    pub fn title(mut self, t: &str) -> Self {
        self.title = Some(t.to_string());
        self
    }

    pub fn align(mut self, align: &[Align]) -> Self {
        assert_eq!(align.len(), self.header.len());
        self.align = align.to_vec();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "table row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Add a horizontal separator row.
    pub fn sep(&mut self) -> &mut Self {
        self.rows.push(vec![]);
        self
    }

    pub fn to_string(&self) -> String {
        let n = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let hline = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..n {
                let c = cells.get(i).map(|x| x.as_str()).unwrap_or("");
                let pad = widths[i] - c.chars().count();
                match self.align[i] {
                    Align::Left => {
                        s.push(' ');
                        s.push_str(c);
                        s.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        s.push_str(&" ".repeat(pad + 1));
                        s.push_str(c);
                        s.push(' ');
                    }
                }
                s.push('|');
            }
            s
        };

        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&hline);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&hline);
        out.push('\n');
        for r in &self.rows {
            if r.is_empty() {
                out.push_str(&hline);
            } else {
                out.push_str(&fmt_row(r));
            }
            out.push('\n');
        }
        out.push_str(&hline);
        out.push('\n');
        out
    }
}

/// Format helper: `1.53`, trimming to the given precision.
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Format a ratio as `3.8x`.
pub fn ratio(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_aligns() {
        let mut t = Table::new(&["name", "val"]).title("demo");
        t.row(&["a".into(), "1.0".into()]);
        t.sep();
        t.row(&["long-name".into(), "22.5".into()]);
        let s = t.to_string();
        assert!(s.contains("demo"));
        assert!(s.contains("| a         |  1.0 |"));
        assert!(s.contains("| long-name | 22.5 |"));
        // all lines same width
        let widths: Vec<usize> =
            s.lines().skip(1).map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(f(1.5349, 2), "1.53");
        assert_eq!(ratio(3.849), "3.85x");
    }
}
