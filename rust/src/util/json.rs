//! Minimal JSON: a value model, a recursive-descent parser, and a
//! writer. Used for the AOT `manifest.json` (runtime side) and the
//! coordinator's results store. Implements the subset of RFC 8259 the
//! framework needs (no surrogate-pair escapes on output).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// JSON value. Objects use a BTreeMap so output is deterministically
/// sorted — diffs of result files stay stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if self is not an object
    /// (programming error, not data error).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Numeric value as an exact non-negative integer (rejects
    /// fractional or negative numbers, unlike the truncating
    /// [`Json::as_usize`]) — the right accessor for counts and sizes
    /// arriving over the wire.
    pub fn as_u64(&self) -> Option<u64> {
        // `u64::MAX as f64` rounds UP to 2^64, so the bound must be
        // strict or 2^64 would silently saturate to u64::MAX.
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = "  ".repeat(depth + 1);
        let pad0 = "  ".repeat(depth);
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    x.write_pretty(out, depth + 1);
                }
                let _ = write!(out, "\n{pad0}]");
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                let _ = write!(out, "\n{pad0}}}");
            }
            _ => self.write(out),
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x}");
        }
    } else {
        // JSON has no NaN/Inf; persist as null like serde_json does.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            )
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: re-decode from the byte slice
                    let start = self.i - 1;
                    let rest = std::str::from_utf8(&self.b[start..])?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like_doc() {
        let text = r#"{
          "gemm_128": {
            "file": "gemm_128.hlo.txt",
            "inputs": [{"shape": [128, 128], "dtype": "float32"}],
            "m": 128, "ok": true, "note": null
          }
        }"#;
        let v = parse(text).unwrap();
        let g = v.get("gemm_128").unwrap();
        assert_eq!(g.get("file").unwrap().as_str().unwrap(), "gemm_128.hlo.txt");
        assert_eq!(g.get("m").unwrap().as_usize().unwrap(), 128);
        let shape = g.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 128);
        // reparse of our own output is identical
        let again = parse(&v.to_string()).unwrap();
        assert_eq!(again, v);
        let again = parse(&v.to_pretty()).unwrap();
        assert_eq!(again, v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}f".to_string());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""café ✓""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ✓");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{,}").is_err());
        assert!(parse("[1 2]").is_err());
        assert!(parse("{\"a\":1} x").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-1.5e3").unwrap().as_f64().unwrap(), -1500.0);
        assert_eq!(parse("0").unwrap().as_f64().unwrap(), 0.0);
        // non-finite serializes as null
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn typed_accessors() {
        assert_eq!(parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(parse("1").unwrap().as_bool(), None);
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(parse("0").unwrap().as_u64(), Some(0));
        assert_eq!(parse("-1").unwrap().as_u64(), None);
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
        assert_eq!(parse("\"7\"").unwrap().as_u64(), None);
        // 2^64 is exactly representable in f64 but not in u64
        assert_eq!(parse("18446744073709551616").unwrap().as_u64(), None);
    }
}
