//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! Used by the trace generator, gpusim address hashing, the property-test
//! kit, and the synthetic-data generator of the e2e example. Fully
//! deterministic across platforms so every experiment is reproducible
//! from its seed (recorded in the results store).

/// splitmix64 step — also usable standalone as a hash/stream seeder.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** by Blackman & Vigna; 2^256-1 period, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
