//! CSV emission for experiment results (one file per table/figure so
//! downstream plotting is trivial).

use std::fs;
use std::path::Path;

use anyhow::Result;

/// Builds a CSV document row by row; quotes only when required.
#[derive(Debug, Default)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Csv { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    /// Append a row; panics if the arity differs from the header
    /// (programming error in a bench harness).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "csv row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: mixed &str/f64 rows via `format!` at the call site.
    pub fn rowv(&mut self, cells: Vec<String>) -> &mut Self {
        self.row(&cells)
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        out.push_str(&join(&self.header));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&join(r));
            out.push('\n');
        }
        out
    }

    pub fn write(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.to_string())?;
        Ok(())
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn header(&self) -> &[String] {
        &self.header
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// JSON view `{"header": [...], "rows": [[...], ...]}`. Cells are
    /// the exact strings the CSV emits (before CSV quoting), so a JSON
    /// consumer sees rows byte-identical to the CSV artifact.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = Json::obj();
        o.set(
            "header",
            Json::Arr(self.header.iter().map(|h| Json::Str(h.clone())).collect()),
        );
        o.set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                    .collect(),
            ),
        );
        o
    }
}

fn join(cells: &[String]) -> String {
    cells.iter().map(|c| quote(c)).collect::<Vec<_>>().join(",")
}

fn quote(cell: &str) -> String {
    if cell.contains([',', '"', '\n']) {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_and_quoted() {
        let mut c = Csv::new(&["name", "value"]);
        c.row(&["plain".into(), "1.5".into()]);
        c.row(&["has,comma".into(), "say \"hi\"".into()]);
        let s = c.to_string();
        assert_eq!(
            s,
            "name,value\nplain,1.5\n\"has,comma\",\"say \"\"hi\"\"\"\n"
        );
        assert_eq!(c.n_rows(), 2);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["only-one".into()]);
    }

    #[test]
    fn json_view_carries_raw_cells() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["has,comma".into(), "2".into()]);
        let j = c.to_json();
        assert_eq!(
            j.get("header").unwrap().as_arr().unwrap()[0].as_str().unwrap(),
            "a"
        );
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        // raw cell, not the CSV-quoted form
        assert_eq!(
            rows[0].as_arr().unwrap()[0].as_str().unwrap(),
            "has,comma"
        );
        assert_eq!(c.header(), ["a".to_string(), "b".to_string()]);
        assert_eq!(c.rows().len(), 1);
    }
}
