//! Unit conventions and formatting.
//!
//! The framework's internal convention (documented once, asserted in
//! tests): **seconds, joules, watts, square millimeters, bytes**. Paper
//! tables are printed via the `fmt_*` helpers in the unit each table
//! uses (ns, nJ, pJ, mW, mm², MB).

pub const NS: f64 = 1e-9;
pub const PS: f64 = 1e-12;
pub const US: f64 = 1e-6;
pub const MS: f64 = 1e-3;

pub const PJ: f64 = 1e-12;
pub const NJ: f64 = 1e-9;
pub const UJ: f64 = 1e-6;

pub const MW: f64 = 1e-3;
pub const UW: f64 = 1e-6;

pub const KB: u64 = 1024;
pub const MB: u64 = 1024 * 1024;

/// mm² per m² (areas are already stored in mm²; this is for the device
/// layer, which computes in m²).
pub const M2_TO_MM2: f64 = 1e6;

pub fn fmt_time(s: f64) -> String {
    if s < 1e-9 {
        format!("{:.1} ps", s / PS)
    } else if s < 1e-6 {
        format!("{:.2} ns", s / NS)
    } else if s < 1e-3 {
        format!("{:.2} us", s / US)
    } else {
        format!("{:.3} s", s)
    }
}

pub fn fmt_energy(j: f64) -> String {
    if j < 1e-10 {
        format!("{:.3} pJ", j / PJ)
    } else if j < 1e-6 {
        format!("{:.3} nJ", j / NJ)
    } else if j < 1e-3 {
        format!("{:.3} uJ", j / UJ)
    } else {
        format!("{:.4} J", j)
    }
}

pub fn fmt_power(w: f64) -> String {
    if w < 1e-3 {
        format!("{:.2} uW", w / UW)
    } else if w < 1.0 {
        format!("{:.1} mW", w / MW)
    } else {
        format!("{:.2} W", w)
    }
}

pub fn fmt_bytes(b: u64) -> String {
    if b >= MB {
        format!("{:.1} MB", b as f64 / MB as f64)
    } else if b >= KB {
        format!("{:.1} KB", b as f64 / KB as f64)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(fmt_time(650.0 * PS), "650.0 ps");
        assert_eq!(fmt_time(2.91 * NS), "2.91 ns");
        assert_eq!(fmt_energy(0.076 * PJ), "0.076 pJ");
        assert_eq!(fmt_energy(0.35 * NJ), "0.350 nJ");
        assert_eq!(fmt_power(6.442), "6.44 W");
        assert_eq!(fmt_power(748.0 * MW), "748.0 mW");
        assert_eq!(fmt_bytes(3 * MB), "3.0 MB");
        assert_eq!(fmt_bytes(48 * KB), "48.0 KB");
    }
}
