//! Summary statistics over experiment series.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Geometric mean — the right mean for normalized ratios (speedups,
/// EDP reductions); all inputs must be > 0.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_ratios() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn running_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - mean(&xs)).abs() < 1e-12);
        assert!((r.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 9.0);
        assert_eq!(r.count(), 8);
    }
}
