//! Property-based testing kit (proptest substitute for the offline
//! image).
//!
//! A property is a closure over a [`Gen`] that draws random inputs and
//! asserts invariants. The runner executes `cases` iterations from a
//! fixed seed (override with env `DEEPNVM_PT_SEED`), and on failure
//! re-raises the panic annotated with the failing case's seed so it can
//! be replayed exactly. Shrinking is per-draw: integer draws are biased
//! toward boundary values (0, 1, max) so most failures are already
//! near-minimal.

use std::panic::{catch_unwind, AssertUnwindSafe};

use super::rng::Rng;

/// Input source handed to properties.
pub struct Gen {
    rng: Rng,
    /// When true, prefer boundary values for ~25% of integer draws.
    edge_bias: bool,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), edge_bias: true }
    }

    /// usize in [lo, hi] inclusive, boundary-biased.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        if self.edge_bias && self.rng.chance(0.25) {
            *self.rng.choose(&[lo, hi, lo + (hi - lo) / 2])
        } else {
            self.rng.range_usize(lo, hi)
        }
    }

    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        if self.edge_bias && self.rng.chance(0.25) {
            *self.rng.choose(&[lo, hi, lo + (hi - lo) / 2])
        } else {
            self.rng.range_u64(lo, hi)
        }
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    /// A power of two in [lo, hi] (both must be powers of two).
    pub fn pow2_in(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo.is_power_of_two() && hi.is_power_of_two() && lo <= hi);
        let lo_exp = lo.trailing_zeros();
        let hi_exp = hi.trailing_zeros();
        1 << self.rng.range_u64(lo_exp as u64, hi_exp as u64)
    }

    /// A vector with length in [min_len, max_len].
    pub fn vec<T>(
        &mut self,
        min_len: usize,
        max_len: usize,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.usize_in(min_len, max_len);
        (0..n).map(|_| f(self)).collect()
    }

    /// Raw RNG access for exotic distributions.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` for `cases` random cases. Panics (test failure) with the
/// case seed on the first violated assertion.
pub fn check(cases: u64, prop: impl Fn(&mut Gen)) {
    let base: u64 = std::env::var("DEEPNVM_PT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xDEE9_4E4D);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut gen = Gen::new(seed);
        let result = catch_unwind(AssertUnwindSafe(|| prop(&mut gen)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| err.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property failed on case {case} (replay: DEEPNVM_PT_SEED={base}, \
                 case seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single case by seed (debugging helper).
pub fn replay(seed: u64, prop: impl Fn(&mut Gen)) {
    let mut gen = Gen::new(seed);
    prop(&mut gen);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        check(100, |g| {
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            assert!(a + b >= a);
        });
    }

    #[test]
    fn reports_failure_with_seed() {
        let r = catch_unwind(|| {
            check(50, |g| {
                let x = g.usize_in(0, 10);
                assert!(x < 10, "hit the boundary x={x}");
            })
        });
        let err = r.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay"), "{msg}");
        assert!(msg.contains("hit the boundary"), "{msg}");
    }

    #[test]
    fn pow2_in_returns_powers() {
        check(200, |g| {
            let p = g.pow2_in(8, 1024);
            assert!(p.is_power_of_two() && (8..=1024).contains(&p));
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Gen::new(9);
        let mut b = Gen::new(9);
        for _ in 0..50 {
            assert_eq!(a.usize_in(0, 1000), b.usize_in(0, 1000));
        }
    }
}
