//! DeepNVM++ CLI entry point. See `deepnvm help`.

fn main() {
    // Die quietly on SIGPIPE (e.g. `deepnvm help | head`) instead of
    // panicking on the failed stdout write.
    unsafe {
        libc::signal(libc::SIGPIPE, libc::SIG_DFL);
    }
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(deepnvm::coordinator::run_cli(&args));
}
