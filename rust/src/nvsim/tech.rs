//! 16nm interconnect + periphery technology parameters ("the internal
//! technology file of NVSim, modified to the corresponding 16nm
//! technology parameters" — paper §III-B), plus the per-technology
//! bitcell wrapper the array model consumes.

use crate::device::{BitcellParams, MemTech};

/// Wire/device constants of the modeled 16nm node. Local (M2-class)
/// wires inside subarrays, intermediate for mat routing, global
/// repeatered wires for the H-tree.
#[derive(Clone, Copy, Debug)]
pub struct TechParams {
    /// Local wire resistance (Ohm/m).
    pub r_wire_local: f64,
    /// Local wire capacitance (F/m).
    pub c_wire_local: f64,
    /// Repeatered global wire delay (s/m).
    pub t_wire_global: f64,
    /// Global wire energy per bit per meter at VDD (J/(bit*m)).
    pub e_wire_global: f64,
    /// Global wire leakage per repeater span (W/m per bit lane).
    pub leak_wire_global: f64,
    /// Supply voltage (V).
    pub vdd: f64,
    /// FO4 inverter delay (s) — decoder stage granularity.
    pub t_fo4: f64,
    /// Energy of one decoder stage driving its load (J).
    pub e_dec_stage: f64,
    /// Sense-amp leakage (W each).
    pub leak_senseamp: f64,
    /// Decoder + driver leakage per subarray row driver (W).
    pub leak_row_driver: f64,
    /// Per-mat control/repeater leakage (W).
    pub leak_mat_ctrl: f64,
    /// Drain capacitance a cell adds to its bitline (F).
    pub c_cell_drain: f64,
    /// Gate capacitance a cell adds to its wordline (F).
    pub c_cell_gate: f64,
}

impl TechParams {
    /// The 16nm node used throughout the paper reproduction.
    pub fn n16() -> Self {
        TechParams {
            r_wire_local: 4.0e6,    // 4 Ohm/um
            c_wire_local: 0.20e-9,  // 0.20 fF/um
            // Semi-global (non-repeated M4-class) routing inside the
            // cache macro — deeply-scaled wires are slow: the paper's
            // strong latency growth with capacity (Table II / Fig 9b)
            // requires ~0.6-0.7 ns/mm, consistent with 16nm RC data.
            t_wire_global: 650e-12 / 1e-3,
            e_wire_global: 0.30e-12 / 1e-3, // 0.30 pJ/bit/mm
            leak_wire_global: 1.2e-6 / 1e-3, // repeater leakage per mm lane
            vdd: 0.8,
            t_fo4: 9e-12,
            e_dec_stage: 0.6e-15,
            leak_senseamp: 1.6e-6,
            leak_row_driver: 0.4e-6,
            leak_mat_ctrl: 60e-6,
            c_cell_drain: 0.10e-15,
            c_cell_gate: 0.10e-15,
        }
    }
}

/// Bitcell geometry + access behaviour as the array model needs it.
#[derive(Clone, Copy, Debug)]
pub struct Bitcell {
    pub params: BitcellParams,
    /// Physical cell area (m^2).
    pub area: f64,
    /// Cell width (along the wordline), m.
    pub width: f64,
    /// Cell height (along the bitline), m.
    pub height: f64,
}

/// Foundry 6T SRAM cell area at the modeled node (m^2) — the Table I
/// normalization base (shared with `device::characterize::layout`).
pub const SRAM_CELL_AREA: f64 = 0.074e-12;

impl Bitcell {
    /// Wrap device-layer parameters with layout geometry. Aspect ratio
    /// (width/height): 6T cells are wide (~2.2), 1T1R MTJ stacks are
    /// roughly square (~1.1).
    pub fn from_params(params: BitcellParams) -> Self {
        let area = params.area_rel * SRAM_CELL_AREA;
        let aspect = match params.tech {
            MemTech::Sram => 2.2,
            MemTech::SttMram => 1.15,
            MemTech::SotMram => 1.15,
        };
        Bitcell {
            params,
            area,
            width: (area * aspect).sqrt(),
            height: (area / aspect).sqrt(),
        }
    }

    /// Paper-calibrated bitcell of the given technology.
    pub fn paper(tech: MemTech) -> Self {
        Self::from_params(BitcellParams::paper(tech))
    }

    /// Local sense time excluding the characterization testbench's
    /// wordline-rise share (the array model computes its own wordline
    /// RC; see device::characterize::WL_RISE).
    pub fn sense_development(&self) -> f64 {
        (self.params.sense_latency - crate::device::characterize::WL_RISE)
            .max(30e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sram_cell_geometry() {
        let c = Bitcell::paper(MemTech::Sram);
        assert!((c.area - SRAM_CELL_AREA).abs() / SRAM_CELL_AREA < 1e-12);
        assert!(c.width > c.height, "6T cells are wide");
        assert!((c.width * c.height - c.area).abs() / c.area < 1e-9);
    }

    #[test]
    fn mram_cells_denser() {
        let sram = Bitcell::paper(MemTech::Sram);
        let stt = Bitcell::paper(MemTech::SttMram);
        let sot = Bitcell::paper(MemTech::SotMram);
        assert!(stt.area < 0.4 * sram.area);
        assert!(sot.area < stt.area);
    }

    #[test]
    fn sense_development_positive() {
        for t in MemTech::ALL {
            let c = Bitcell::paper(t);
            assert!(c.sense_development() > 0.0, "{t}");
            assert!(c.sense_development() < c.params.sense_latency);
        }
    }
}
