//! Interconnect + periphery technology parameters ("the internal
//! technology file of NVSim", paper §III-B), node-indexed: the 16 nm
//! set the paper reproduces, plus deeply-scaled 7/5 nm calibrations
//! (the journal extension's scalability axis), plus the per-technology
//! bitcell wrapper the array model consumes.

use crate::device::{BitcellParams, MemTech, UncalibratedNode};

/// Wire/device constants of one calibrated node. Local (M2-class)
/// wires inside subarrays, intermediate for mat routing, global
/// repeatered wires for the H-tree.
#[derive(Clone, Copy, Debug)]
pub struct TechParams {
    /// The process node these parameters calibrate (nm).
    pub node_nm: u32,
    /// Local wire resistance (Ohm/m).
    pub r_wire_local: f64,
    /// Local wire capacitance (F/m).
    pub c_wire_local: f64,
    /// Repeatered global wire delay (s/m).
    pub t_wire_global: f64,
    /// Global wire energy per bit per meter at VDD (J/(bit*m)).
    pub e_wire_global: f64,
    /// Global wire leakage per repeater span (W/m per bit lane).
    pub leak_wire_global: f64,
    /// Supply voltage (V).
    pub vdd: f64,
    /// FO4 inverter delay (s) — decoder stage granularity.
    pub t_fo4: f64,
    /// Energy of one decoder stage driving its load (J).
    pub e_dec_stage: f64,
    /// Sense-amp leakage (W each).
    pub leak_senseamp: f64,
    /// Decoder + driver leakage per subarray row driver (W).
    pub leak_row_driver: f64,
    /// Per-mat control/repeater leakage (W).
    pub leak_mat_ctrl: f64,
    /// Drain capacitance a cell adds to its bitline (F).
    pub c_cell_drain: f64,
    /// Gate capacitance a cell adds to its wordline (F).
    pub c_cell_gate: f64,
    /// Foundry 6T SRAM cell area at this node (m^2) — read from the
    /// device layer's layout tables, never duplicated here; the array
    /// model's tag arrays and the Table I normalization share it.
    pub sram_cell_area: f64,
    /// Linear shrink of the absolute peripheral strip dimensions
    /// (sense-amp / decoder silicon) relative to the 16 nm layout.
    pub periph_scale: f64,
}

impl TechParams {
    /// The 16nm node used throughout the paper reproduction.
    pub fn n16() -> Self {
        TechParams {
            node_nm: 16,
            r_wire_local: 4.0e6,    // 4 Ohm/um
            c_wire_local: 0.20e-9,  // 0.20 fF/um
            // Semi-global (non-repeated M4-class) routing inside the
            // cache macro — deeply-scaled wires are slow: the paper's
            // strong latency growth with capacity (Table II / Fig 9b)
            // requires ~0.6-0.7 ns/mm, consistent with 16nm RC data.
            t_wire_global: 650e-12 / 1e-3,
            e_wire_global: 0.30e-12 / 1e-3, // 0.30 pJ/bit/mm
            leak_wire_global: 1.2e-6 / 1e-3, // repeater leakage per mm lane
            vdd: 0.8,
            t_fo4: 9e-12,
            e_dec_stage: 0.6e-15,
            leak_senseamp: 1.6e-6,
            leak_row_driver: 0.4e-6,
            leak_mat_ctrl: 60e-6,
            c_cell_drain: 0.10e-15,
            c_cell_gate: 0.10e-15,
            sram_cell_area: crate::device::characterize::layout::Layout::n16()
                .sram_cell_area,
            periph_scale: 1.0,
        }
    }

    /// 7nm calibration. Devices get faster (FO4 9 -> 6.5 ps) and
    /// cheaper (CV^2 at VDD 0.7 V), but wires get *worse* per unit
    /// length (narrower lines, resistivity size effect) and leakage
    /// per instance rises — the deep-scaling regime where the journal
    /// extension and the 7 nm SOT-DTCO study show NVM pulling further
    /// ahead of SRAM.
    pub fn n7() -> Self {
        TechParams {
            node_nm: 7,
            r_wire_local: 9.0e6,    // 9 Ohm/um
            c_wire_local: 0.19e-9,
            t_wire_global: 850e-12 / 1e-3,
            e_wire_global: 0.21e-12 / 1e-3,
            leak_wire_global: 1.6e-6 / 1e-3,
            vdd: 0.7,
            t_fo4: 6.5e-12,
            e_dec_stage: 0.35e-15,
            leak_senseamp: 1.9e-6,
            leak_row_driver: 0.5e-6,
            leak_mat_ctrl: 75e-6,
            c_cell_drain: 0.06e-15,
            c_cell_gate: 0.06e-15,
            sram_cell_area: crate::device::characterize::layout::Layout::n7()
                .sram_cell_area,
            periph_scale: 0.60,
        }
    }

    /// 5nm calibration (see [`TechParams::n7`] for the scaling story;
    /// every trend continues: faster gates, slower wires, leakier
    /// silicon per instance).
    pub fn n5() -> Self {
        TechParams {
            node_nm: 5,
            r_wire_local: 12.5e6,   // 12.5 Ohm/um
            c_wire_local: 0.18e-9,
            t_wire_global: 980e-12 / 1e-3,
            e_wire_global: 0.17e-12 / 1e-3,
            leak_wire_global: 1.9e-6 / 1e-3,
            vdd: 0.65,
            t_fo4: 5.8e-12,
            e_dec_stage: 0.28e-15,
            leak_senseamp: 2.1e-6,
            leak_row_driver: 0.55e-6,
            leak_mat_ctrl: 85e-6,
            c_cell_drain: 0.05e-15,
            c_cell_gate: 0.05e-15,
            sram_cell_area: crate::device::characterize::layout::Layout::n5()
                .sram_cell_area,
            periph_scale: 0.50,
        }
    }

    /// Technology parameters for a calibrated node.
    pub fn at(node_nm: u32) -> Result<Self, UncalibratedNode> {
        Ok(match node_nm {
            16 => Self::n16(),
            7 => Self::n7(),
            5 => Self::n5(),
            other => return Err(UncalibratedNode(other)),
        })
    }
}

/// Bitcell geometry + access behaviour as the array model needs it.
#[derive(Clone, Copy, Debug)]
pub struct Bitcell {
    pub params: BitcellParams,
    /// Physical cell area (m^2).
    pub area: f64,
    /// Cell width (along the wordline), m.
    pub width: f64,
    /// Cell height (along the bitline), m.
    pub height: f64,
}

/// Foundry 6T SRAM cell area at a calibrated node (m^2) — delegates to
/// the device layer's layout tables, the single source of truth shared
/// with `device::characterize`.
pub fn sram_cell_area(node_nm: u32) -> Result<f64, UncalibratedNode> {
    crate::device::sram_cell_area(node_nm)
}

impl Bitcell {
    /// Wrap device-layer parameters with 16 nm layout geometry. Aspect
    /// ratio (width/height): 6T cells are wide (~2.2), 1T1R MTJ stacks
    /// are roughly square (~1.1).
    pub fn from_params(params: BitcellParams) -> Self {
        Self::from_params_at(params, 16).expect("16 nm is calibrated")
    }

    /// As [`Bitcell::from_params`] against an explicit node's SRAM
    /// area base (`area_rel` is relative to the same-node SRAM cell).
    pub fn from_params_at(
        params: BitcellParams,
        node_nm: u32,
    ) -> Result<Self, UncalibratedNode> {
        let area = params.area_rel * sram_cell_area(node_nm)?;
        let aspect = match params.tech {
            MemTech::Sram => 2.2,
            MemTech::SttMram => 1.15,
            MemTech::SotMram => 1.15,
        };
        Ok(Bitcell {
            params,
            area,
            width: (area * aspect).sqrt(),
            height: (area / aspect).sqrt(),
        })
    }

    /// Paper-calibrated bitcell of the given technology (16 nm).
    pub fn paper(tech: MemTech) -> Self {
        Self::from_params(BitcellParams::paper(tech))
    }

    /// Calibrated bitcell of the given technology at a process node.
    pub fn at(tech: MemTech, node_nm: u32) -> Result<Self, UncalibratedNode> {
        Self::from_params_at(BitcellParams::paper_at(tech, node_nm)?, node_nm)
    }

    /// Local sense time excluding the characterization testbench's
    /// wordline-rise share (the array model computes its own wordline
    /// RC; see device::characterize::WL_RISE).
    pub fn sense_development(&self) -> f64 {
        (self.params.sense_latency - crate::device::characterize::WL_RISE)
            .max(30e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sram_cell_geometry() {
        let c = Bitcell::paper(MemTech::Sram);
        let base = sram_cell_area(16).unwrap();
        assert!((c.area - base).abs() / base < 1e-12);
        assert!(c.width > c.height, "6T cells are wide");
        assert!((c.width * c.height - c.area).abs() / c.area < 1e-9);
    }

    #[test]
    fn mram_cells_denser() {
        let sram = Bitcell::paper(MemTech::Sram);
        let stt = Bitcell::paper(MemTech::SttMram);
        let sot = Bitcell::paper(MemTech::SotMram);
        assert!(stt.area < 0.4 * sram.area);
        assert!(sot.area < stt.area);
    }

    #[test]
    fn sense_development_positive() {
        for t in MemTech::ALL {
            let c = Bitcell::paper(t);
            assert!(c.sense_development() > 0.0, "{t}");
            assert!(c.sense_development() < c.params.sense_latency);
        }
    }

    #[test]
    fn node_params_follow_scaling_trends() {
        let n16 = TechParams::n16();
        let n7 = TechParams::n7();
        let n5 = TechParams::n5();
        for (a, b) in [(&n16, &n7), (&n7, &n5)] {
            assert!(b.vdd < a.vdd, "supply drops with the node");
            assert!(b.t_fo4 < a.t_fo4, "gates speed up");
            assert!(b.e_dec_stage < a.e_dec_stage, "CV^2 shrinks");
            assert!(b.r_wire_local > a.r_wire_local, "wires worsen");
            assert!(b.t_wire_global > a.t_wire_global);
            assert!(b.sram_cell_area < a.sram_cell_area, "cells shrink");
            assert!(b.periph_scale < a.periph_scale);
        }
        assert_eq!(TechParams::at(16).unwrap().node_nm, 16);
        assert_eq!(TechParams::at(7).unwrap().node_nm, 7);
        assert_eq!(TechParams::at(5).unwrap().node_nm, 5);
        assert!(TechParams::at(10).is_err());
    }

    #[test]
    fn node_indexed_bitcells() {
        for t in MemTech::ALL {
            let b16 = Bitcell::at(t, 16).unwrap();
            let b7 = Bitcell::at(t, 7).unwrap();
            let b5 = Bitcell::at(t, 5).unwrap();
            // 16 nm accessor is the paper cell, bit for bit
            assert_eq!(b16.area, Bitcell::paper(t).area, "{t}");
            assert!(b7.area < b16.area, "{t} cells shrink at 7nm");
            assert!(b5.area < b7.area, "{t} cells shrink at 5nm");
            assert!(b7.sense_development() > 0.0);
        }
        // the MRAM-vs-SRAM density edge narrows but survives
        let sram7 = Bitcell::at(MemTech::Sram, 7).unwrap();
        let stt7 = Bitcell::at(MemTech::SttMram, 7).unwrap();
        assert!(stt7.area < sram7.area);
        assert!(
            stt7.area / sram7.area
                > Bitcell::paper(MemTech::SttMram).area / Bitcell::paper(MemTech::Sram).area
        );
        assert!(Bitcell::at(MemTech::Sram, 9).is_err());
    }
}
