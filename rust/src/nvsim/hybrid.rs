//! Hybrid SRAM + NVM cache exploration (paper §II cites hybrid caches
//! [28]-[31] as the main prior-art mitigation for NVM write cost; this
//! module adds them to the design space so DeepNVM++ can evaluate the
//! approach its related work describes).
//!
//! Model: a way-partitioned last-level cache — `sram_ways` of the 16
//! ways in SRAM, the rest in an NVM technology. Write-heavy lines are
//! steered to the SRAM ways by the (modeled) placement policy, so the
//! effective write cost is a mix weighted by the steering hit rate;
//! reads sample ways uniformly. Leakage and area compose linearly from
//! the per-technology designs.

use crate::device::MemTech;

use super::explorer::tuned_cache;
use super::model::CachePpa;
use super::org::ASSOC;

/// A hybrid way-partitioned design.
#[derive(Clone, Copy, Debug)]
pub struct HybridDesign {
    pub nvm: MemTech,
    /// Ways implemented in SRAM (0..=ASSOC); the rest are NVM.
    pub sram_ways: u32,
    /// Fraction of writes the placement policy lands in SRAM ways
    /// (write-steering efficiency; [29]-class policies reach ~0.8-0.9).
    pub steer: f64,
    pub ppa: CachePpa,
}

/// Compose the PPA of a hybrid cache at `capacity_bytes`.
///
/// A way-partitioned hybrid is *one* array organization whose way
/// groups are fabricated in different technologies, so the composition
/// uses the full-capacity EDAP-tuned design of each technology (wire
/// lengths, decoders and H-tree are shared) and scales the per-way
/// quantities (leakage, area, per-access cell costs) by the way
/// fraction. This keeps the sweep free of exact-capacity enumeration
/// artifacts and is monotone by construction.
pub fn hybrid(
    nvm: MemTech,
    capacity_bytes: u64,
    sram_ways: u32,
    steer: f64,
) -> HybridDesign {
    assert!(nvm.is_nvm(), "hybrid partner must be an NVM");
    assert!(sram_ways as usize <= ASSOC);
    let f_sram = sram_ways as f64 / ASSOC as f64;
    let f_nvm = 1.0 - f_sram;

    let s = tuned_cache(MemTech::Sram, capacity_bytes).ppa;
    let n = tuned_cache(nvm, capacity_bytes).ppa;

    // Reads sample ways by capacity share; writes follow the steering
    // policy (steered writes pay SRAM cost, the rest pay NVM cost).
    // Steering cannot place more writes in SRAM ways than exist; with
    // no SRAM ways it places none.
    let w_sram = if sram_ways == 0 { 0.0 } else { steer.max(f_sram) };
    let ppa = CachePpa {
        read_latency: f_sram * s.read_latency + f_nvm * n.read_latency,
        write_latency: w_sram * s.write_latency + (1.0 - w_sram) * n.write_latency,
        read_energy: f_sram * s.read_energy + f_nvm * n.read_energy,
        write_energy: w_sram * s.write_energy + (1.0 - w_sram) * n.write_energy,
        leakage_power: f_sram * s.leakage_power + f_nvm * n.leakage_power,
        area: f_sram * s.area + f_nvm * n.area,
    };
    HybridDesign { nvm, sram_ways, steer, ppa }
}

/// Sweep SRAM-way counts for one NVM partner.
pub fn sweep(nvm: MemTech, capacity_bytes: u64, steer: f64) -> Vec<HybridDesign> {
    (0..=ASSOC as u32)
        .step_by(2)
        .map(|w| hybrid(nvm, capacity_bytes, w, steer))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1024 * 1024;

    #[test]
    fn endpoints_are_pure_caches() {
        let pure_stt = tuned_cache(MemTech::SttMram, 3 * MB).ppa;
        let pure_sram = tuned_cache(MemTech::Sram, 3 * MB).ppa;
        let h0 = hybrid(MemTech::SttMram, 3 * MB, 0, 0.85);
        let h16 = hybrid(MemTech::SttMram, 3 * MB, 16, 0.85);
        assert!((h0.ppa.write_latency - pure_stt.write_latency).abs() < 1e-12);
        assert!((h0.ppa.leakage_power - pure_stt.leakage_power).abs() < 1e-9);
        assert!((h16.ppa.leakage_power - pure_sram.leakage_power).abs() < 1e-9);
        assert!((h16.ppa.write_latency - pure_sram.write_latency).abs() < 1e-12);
    }

    #[test]
    fn hybrid_trades_write_latency_for_leakage() {
        // vs pure STT: adding SRAM ways buys write latency and costs
        // leakage. (Within the steered plateau the mix barely moves, so
        // the tradeoff is asserted at the endpoints and the first step.)
        let sweep = sweep(MemTech::SttMram, 3 * MB, 0.85);
        let pure_nvm = sweep.first().unwrap().ppa;
        let first_hybrid = sweep[1].ppa;
        let pure_sram = sweep.last().unwrap().ppa;
        assert!(first_hybrid.write_latency < 0.5 * pure_nvm.write_latency);
        assert!(first_hybrid.leakage_power > pure_nvm.leakage_power);
        assert!(pure_sram.leakage_power > first_hybrid.leakage_power);
        // leakage is monotone across the sweep
        for pair in sweep.windows(2) {
            assert!(
                pair[1].ppa.leakage_power >= pair[0].ppa.leakage_power * 0.999,
                "leakage must rise with SRAM ways"
            );
        }
    }

    #[test]
    fn small_sram_partition_fixes_stt_writes_cheaply() {
        // The related-work claim: a few SRAM ways absorb most of the
        // write-latency pain at a fraction of the SRAM leakage.
        let pure_stt = hybrid(MemTech::SttMram, 3 * MB, 0, 0.85).ppa;
        let pure_sram = hybrid(MemTech::SttMram, 3 * MB, 16, 0.85).ppa;
        let h4 = hybrid(MemTech::SttMram, 3 * MB, 4, 0.85).ppa;
        // write latency within 2.5x of SRAM (vs ~5x for pure STT)
        assert!(h4.write_latency < 2.5 * pure_sram.write_latency);
        assert!(pure_stt.write_latency > 4.0 * pure_sram.write_latency);
        // while keeping leakage under half of pure SRAM
        assert!(h4.leakage_power < 0.5 * pure_sram.leakage_power);
    }

    #[test]
    fn better_steering_helps_stt_writes_only() {
        // Steering matters for STT (SRAM writes are far cheaper/faster
        // than STT writes); it must not touch reads or leakage.
        let lo = hybrid(MemTech::SttMram, 3 * MB, 4, 0.3).ppa;
        let hi = hybrid(MemTech::SttMram, 3 * MB, 4, 0.95).ppa;
        assert!(hi.write_latency < lo.write_latency);
        assert_eq!(hi.read_energy, lo.read_energy);
        assert_eq!(hi.leakage_power, lo.leakage_power);
    }

    #[test]
    fn sot_does_not_need_a_hybrid() {
        // SOT's own writes are already cheaper than SRAM's, so hybrid
        // partitions only add leakage — consistent with the hybrid
        // literature being an STT story.
        let pure_sot = hybrid(MemTech::SotMram, 3 * MB, 0, 0.85).ppa;
        let h4 = hybrid(MemTech::SotMram, 3 * MB, 4, 0.85).ppa;
        assert!(h4.leakage_power > pure_sot.leakage_power);
        assert!(h4.write_energy >= pure_sot.write_energy * 0.95);
    }

    #[test]
    #[should_panic(expected = "hybrid partner must be an NVM")]
    fn rejects_sram_sram_hybrid() {
        hybrid(MemTech::Sram, 3 * MB, 4, 0.8);
    }
}
