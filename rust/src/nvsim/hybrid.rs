//! Hybrid SRAM + NVM cache exploration (paper §II cites hybrid caches
//! [28]-[31] as the main prior-art mitigation for NVM write cost; this
//! module adds them to the design space so DeepNVM++ can evaluate the
//! approach its related work describes).
//!
//! Model: a way-partitioned last-level cache — `sram_ways` of the 16
//! ways in SRAM, the rest in an NVM technology. Write-heavy lines are
//! steered to the SRAM ways by the (modeled) placement policy, so the
//! effective write cost is a mix weighted by the steering hit rate;
//! reads sample ways uniformly. Leakage and area compose linearly from
//! the per-technology designs.
//!
//! [`TechSel`] is the sweep-facing handle: a grid's tech axis is a list
//! of selections, each either a pure [`MemTech`] or a
//! [`HybridSel`] way partition. The sweep memo composes hybrid PPA from
//! its cached pure circuit solves via [`compose_ppa`], so a hybrid
//! point never triggers a separate circuit solve.

use std::fmt;

use crate::device::{MemTech, UncalibratedNode};

use super::explorer::tuned_cache_at;
use super::model::CachePpa;
use super::org::ASSOC;

/// A way-partitioned hybrid selection: `sram_ways` of the cache's
/// [`ASSOC`] ways in SRAM, the rest in `nvm`, with the placement
/// policy landing `steer()` of writes in the SRAM ways. Steering is
/// stored in basis points so the selection stays `Copy + Eq + Hash`
/// and binds bit-exactly into grid keys and shard payload hashes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct HybridSel {
    pub nvm: MemTech,
    /// Ways implemented in SRAM (0..=ASSOC); the rest are NVM.
    pub sram_ways: u8,
    /// Write-steering efficiency in basis points (8500 = 0.85).
    pub steer_bp: u16,
}

impl HybridSel {
    /// Steering efficiency as a fraction in [0, 1].
    pub fn steer(&self) -> f64 {
        self.steer_bp as f64 / 1e4
    }

    fn nvm_code(&self) -> &'static str {
        match self.nvm {
            MemTech::SttMram => "stt",
            MemTech::SotMram => "sot",
            // rejected by every construction path; named for Display
            MemTech::Sram => "sram",
        }
    }

    /// Canonical spelling, e.g. `hybrid-stt:4@0.85` — the inverse of
    /// `sweep::spec::parse_tech_sel`.
    pub fn name(&self) -> String {
        format!("hybrid-{}:{}@{}", self.nvm_code(), self.sram_ways, self.steer())
    }
}

/// One selection on the sweep's tech axis: a pure technology or a
/// way-partitioned hybrid. `Copy + Eq + Hash` so grid points stay
/// value types and the hybrid parameters bind into every content
/// address (grid keys, point payload hashes) with no extra plumbing.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TechSel {
    Pure(MemTech),
    Hybrid(HybridSel),
}

impl TechSel {
    /// Canonical name (pure names match [`MemTech::name`]); the
    /// inverse of `sweep::spec::parse_tech_sel`.
    pub fn name(&self) -> String {
        match self {
            TechSel::Pure(t) => t.name().to_string(),
            TechSel::Hybrid(h) => h.name(),
        }
    }

    /// Whether the selection stores bits in an NVM (hybrids do: the
    /// bulk ways are NVM; only pure SRAM is not).
    pub fn is_nvm(&self) -> bool {
        match self {
            TechSel::Pure(t) => t.is_nvm(),
            TechSel::Hybrid(_) => true,
        }
    }

    /// The pure technology, if this is not a hybrid.
    pub fn pure(&self) -> Option<MemTech> {
        match self {
            TechSel::Pure(t) => Some(*t),
            TechSel::Hybrid(_) => None,
        }
    }

    /// The pure circuit solves this selection's PPA composes from.
    pub fn circuit_deps(&self) -> Vec<MemTech> {
        match self {
            TechSel::Pure(t) => vec![*t],
            TechSel::Hybrid(h) => vec![MemTech::Sram, h.nvm],
        }
    }

    /// Wrap a pure-technology list (the common construction).
    pub fn pures(techs: &[MemTech]) -> Vec<TechSel> {
        techs.iter().copied().map(TechSel::Pure).collect()
    }

    /// All pure technologies — the default tech axis.
    pub fn pure_all() -> Vec<TechSel> {
        Self::pures(&MemTech::ALL)
    }
}

impl From<MemTech> for TechSel {
    fn from(t: MemTech) -> TechSel {
        TechSel::Pure(t)
    }
}

// A selection equals a bare technology iff it is that pure technology
// (hybrids never alias a pure tech). Keeps grid comparisons readable
// at every pre-hybrid call site.
impl PartialEq<MemTech> for TechSel {
    fn eq(&self, other: &MemTech) -> bool {
        self.pure() == Some(*other)
    }
}

impl PartialEq<TechSel> for MemTech {
    fn eq(&self, other: &TechSel) -> bool {
        other.pure() == Some(*self)
    }
}

impl fmt::Display for TechSel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.name())
    }
}

/// A hybrid way-partitioned design.
#[derive(Clone, Copy, Debug)]
pub struct HybridDesign {
    pub nvm: MemTech,
    /// Ways implemented in SRAM (0..=ASSOC); the rest are NVM.
    pub sram_ways: u32,
    /// Fraction of writes the placement policy lands in SRAM ways
    /// (write-steering efficiency; [29]-class policies reach ~0.8-0.9).
    pub steer: f64,
    pub ppa: CachePpa,
}

/// Compose hybrid PPA from the two partners' tuned designs.
///
/// A way-partitioned hybrid is *one* array organization whose way
/// groups are fabricated in different technologies, so the composition
/// uses the full-capacity EDAP-tuned design of each technology (wire
/// lengths, decoders and H-tree are shared) and scales the per-way
/// quantities (leakage, area, per-access cell costs) by the way
/// fraction. Every field is affine in the SRAM way fraction (writes:
/// piecewise-affine, constant on the steered plateau), which is what
/// lets the optimizer's per-slice lower bounds stay admissible for
/// hybrid columns with no extra math.
pub fn compose_ppa(s: &CachePpa, n: &CachePpa, sram_ways: u32, steer: f64) -> CachePpa {
    assert!(sram_ways as usize <= ASSOC);
    let f_sram = sram_ways as f64 / ASSOC as f64;
    let f_nvm = 1.0 - f_sram;
    // Reads sample ways by capacity share; writes follow the steering
    // policy (steered writes pay SRAM cost, the rest pay NVM cost).
    // Steering cannot place more writes in SRAM ways than exist; with
    // no SRAM ways it places none.
    let w_sram = if sram_ways == 0 { 0.0 } else { steer.max(f_sram) };
    CachePpa {
        read_latency: f_sram * s.read_latency + f_nvm * n.read_latency,
        write_latency: w_sram * s.write_latency + (1.0 - w_sram) * n.write_latency,
        read_energy: f_sram * s.read_energy + f_nvm * n.read_energy,
        write_energy: w_sram * s.write_energy + (1.0 - w_sram) * n.write_energy,
        leakage_power: f_sram * s.leakage_power + f_nvm * n.leakage_power,
        area: f_sram * s.area + f_nvm * n.area,
    }
}

/// Compose the PPA of a hybrid cache at `capacity_bytes` on the
/// paper's 16 nm node (legacy entry point; see [`hybrid_at`]).
pub fn hybrid(
    nvm: MemTech,
    capacity_bytes: u64,
    sram_ways: u32,
    steer: f64,
) -> HybridDesign {
    hybrid_at(nvm, capacity_bytes, sram_ways, steer, 16).expect("16 nm is calibrated")
}

/// As [`hybrid`] at an explicit process node: both partner designs are
/// tuned with that node's interconnect and bitcell calibration, so a
/// 7 nm hybrid inherits 7 nm SRAM leakage and 7 nm MRAM density — not
/// the 16 nm table. Returns a typed error for uncalibrated nodes.
pub fn hybrid_at(
    nvm: MemTech,
    capacity_bytes: u64,
    sram_ways: u32,
    steer: f64,
    node_nm: u32,
) -> Result<HybridDesign, UncalibratedNode> {
    assert!(nvm.is_nvm(), "hybrid partner must be an NVM");
    assert!(sram_ways as usize <= ASSOC);
    let s = tuned_cache_at(MemTech::Sram, capacity_bytes, node_nm)?.ppa;
    let n = tuned_cache_at(nvm, capacity_bytes, node_nm)?.ppa;
    let ppa = compose_ppa(&s, &n, sram_ways, steer);
    Ok(HybridDesign { nvm, sram_ways, steer, ppa })
}

/// Sweep SRAM-way counts for one NVM partner.
pub fn sweep(nvm: MemTech, capacity_bytes: u64, steer: f64) -> Vec<HybridDesign> {
    (0..=ASSOC as u32)
        .step_by(2)
        .map(|w| hybrid(nvm, capacity_bytes, w, steer))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nvsim::tuned_cache;

    const MB: u64 = 1024 * 1024;

    #[test]
    fn endpoints_are_pure_caches() {
        let pure_stt = tuned_cache(MemTech::SttMram, 3 * MB).ppa;
        let pure_sram = tuned_cache(MemTech::Sram, 3 * MB).ppa;
        let h0 = hybrid(MemTech::SttMram, 3 * MB, 0, 0.85);
        let h16 = hybrid(MemTech::SttMram, 3 * MB, 16, 0.85);
        assert!((h0.ppa.write_latency - pure_stt.write_latency).abs() < 1e-12);
        assert!((h0.ppa.leakage_power - pure_stt.leakage_power).abs() < 1e-9);
        assert!((h16.ppa.leakage_power - pure_sram.leakage_power).abs() < 1e-9);
        assert!((h16.ppa.write_latency - pure_sram.write_latency).abs() < 1e-12);
    }

    #[test]
    fn hybrid_trades_write_latency_for_leakage() {
        // vs pure STT: adding SRAM ways buys write latency and costs
        // leakage. (Within the steered plateau the mix barely moves, so
        // the tradeoff is asserted at the endpoints and the first step.)
        let sweep = sweep(MemTech::SttMram, 3 * MB, 0.85);
        let pure_nvm = sweep.first().unwrap().ppa;
        let first_hybrid = sweep[1].ppa;
        let pure_sram = sweep.last().unwrap().ppa;
        assert!(first_hybrid.write_latency < 0.5 * pure_nvm.write_latency);
        assert!(first_hybrid.leakage_power > pure_nvm.leakage_power);
        assert!(pure_sram.leakage_power > first_hybrid.leakage_power);
        // leakage is monotone across the sweep
        for pair in sweep.windows(2) {
            assert!(
                pair[1].ppa.leakage_power >= pair[0].ppa.leakage_power * 0.999,
                "leakage must rise with SRAM ways"
            );
        }
    }

    #[test]
    fn small_sram_partition_fixes_stt_writes_cheaply() {
        // The related-work claim: a few SRAM ways absorb most of the
        // write-latency pain at a fraction of the SRAM leakage.
        let pure_stt = hybrid(MemTech::SttMram, 3 * MB, 0, 0.85).ppa;
        let pure_sram = hybrid(MemTech::SttMram, 3 * MB, 16, 0.85).ppa;
        let h4 = hybrid(MemTech::SttMram, 3 * MB, 4, 0.85).ppa;
        // write latency within 2.5x of SRAM (vs ~5x for pure STT)
        assert!(h4.write_latency < 2.5 * pure_sram.write_latency);
        assert!(pure_stt.write_latency > 4.0 * pure_sram.write_latency);
        // while keeping leakage under half of pure SRAM
        assert!(h4.leakage_power < 0.5 * pure_sram.leakage_power);
    }

    #[test]
    fn better_steering_helps_stt_writes_only() {
        // Steering matters for STT (SRAM writes are far cheaper/faster
        // than STT writes); it must not touch reads or leakage.
        let lo = hybrid(MemTech::SttMram, 3 * MB, 4, 0.3).ppa;
        let hi = hybrid(MemTech::SttMram, 3 * MB, 4, 0.95).ppa;
        assert!(hi.write_latency < lo.write_latency);
        assert_eq!(hi.read_energy, lo.read_energy);
        assert_eq!(hi.leakage_power, lo.leakage_power);
    }

    #[test]
    fn sot_does_not_need_a_hybrid() {
        // SOT's own writes are already cheaper than SRAM's, so hybrid
        // partitions only add leakage — consistent with the hybrid
        // literature being an STT story.
        let pure_sot = hybrid(MemTech::SotMram, 3 * MB, 0, 0.85).ppa;
        let h4 = hybrid(MemTech::SotMram, 3 * MB, 4, 0.85).ppa;
        assert!(h4.leakage_power > pure_sot.leakage_power);
        assert!(h4.write_energy >= pure_sot.write_energy * 0.95);
    }

    #[test]
    #[should_panic(expected = "hybrid partner must be an NVM")]
    fn rejects_sram_sram_hybrid() {
        hybrid(MemTech::Sram, 3 * MB, 4, 0.8);
    }

    #[test]
    fn hybrid_at_is_node_distinct() {
        // 16 nm through the node-aware entry point is the legacy design
        let legacy = hybrid(MemTech::SttMram, 3 * MB, 4, 0.85);
        let at16 = hybrid_at(MemTech::SttMram, 3 * MB, 4, 0.85, 16).unwrap();
        assert_eq!(format!("{:?}", legacy.ppa), format!("{:?}", at16.ppa));

        // a 7 nm hybrid composes from 7 nm partner designs — denser
        // and genuinely different from the 16 nm composition (the bug
        // this pins: the old path always solved partners at 16 nm)
        let n7 = hybrid_at(MemTech::SttMram, 3 * MB, 4, 0.85, 7).unwrap();
        assert!(n7.ppa.area < at16.ppa.area, "7nm hybrid must be denser");
        assert_ne!(
            format!("{:?}", n7.ppa),
            format!("{:?}", at16.ppa),
            "hybrid nodes must not alias"
        );
        // uncalibrated nodes error instead of panicking
        assert!(hybrid_at(MemTech::SttMram, 3 * MB, 4, 0.85, 9).is_err());
    }

    #[test]
    fn composition_is_affine_in_way_fraction() {
        // On the steered plateau (steer >= f_sram) every PPA field is
        // affine in sram_ways — the premise the optimizer's per-slice
        // lower bounds rest on.
        let h4 = hybrid(MemTech::SttMram, 3 * MB, 4, 0.85).ppa;
        let h8 = hybrid(MemTech::SttMram, 3 * MB, 8, 0.85).ppa;
        let h12 = hybrid(MemTech::SttMram, 3 * MB, 12, 0.85).ppa;
        for (mid, lo, hi) in [
            (h8.read_latency, h4.read_latency, h12.read_latency),
            (h8.read_energy, h4.read_energy, h12.read_energy),
            (h8.leakage_power, h4.leakage_power, h12.leakage_power),
            (h8.area, h4.area, h12.area),
        ] {
            let interp = 0.5 * (lo + hi);
            assert!((mid - interp).abs() <= 1e-9 * mid.abs().max(interp.abs()));
        }
        // and writes are constant on the plateau
        assert_eq!(h4.write_latency.to_bits(), h12.write_latency.to_bits());
    }

    #[test]
    fn techsel_names_and_helpers() {
        let stt: TechSel = MemTech::SttMram.into();
        assert_eq!(stt.name(), "STT-MRAM");
        assert_eq!(stt.pure(), Some(MemTech::SttMram));
        assert_eq!(stt.circuit_deps(), vec![MemTech::SttMram]);
        assert!(stt.is_nvm() && !TechSel::Pure(MemTech::Sram).is_nvm());

        let h = TechSel::Hybrid(HybridSel {
            nvm: MemTech::SttMram,
            sram_ways: 4,
            steer_bp: 8500,
        });
        assert_eq!(h.name(), "hybrid-stt:4@0.85");
        assert_eq!(h.to_string(), "hybrid-stt:4@0.85");
        assert_eq!(h.pure(), None);
        assert!(h.is_nvm(), "hybrid bulk ways are NVM");
        assert_eq!(h.circuit_deps(), vec![MemTech::Sram, MemTech::SttMram]);

        assert_eq!(TechSel::pure_all().len(), MemTech::ALL.len());
        assert!(TechSel::pure_all().iter().all(|t| t.pure().is_some()));
    }
}
