//! Cache organization: the structural design space Algorithm 1
//! enumerates (banks x mats x subarray geometry x column mux) plus the
//! NVSim access modes.

/// Cache line size in bytes (GPU L2: 128 B lines, 32 B sectors).
pub const LINE_BYTES: usize = 128;
/// Sector granularity of one L2 transaction (GPU L2 reads/writes 32 B).
pub const SECTOR_BYTES: usize = 32;
/// Associativity of the modeled L2 (GTX 1080 Ti: 16-way).
pub const ASSOC: usize = 16;
/// Tag + state bits per line (40-bit PA class).
pub const TAG_BITS_PER_LINE: usize = 24;

/// NVSim access modes (paper Algorithm 1's set A).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AccessMode {
    /// Activate the full row, read tag + all ways in parallel.
    Normal,
    /// Overfetch aggressively for latency (bigger periphery).
    Fast,
    /// Tag first, then only the matching way (serial, low energy).
    Sequential,
}

impl AccessMode {
    pub const ALL: [AccessMode; 3] =
        [AccessMode::Normal, AccessMode::Fast, AccessMode::Sequential];

    pub fn name(&self) -> &'static str {
        match self {
            AccessMode::Normal => "Normal",
            AccessMode::Fast => "Fast",
            AccessMode::Sequential => "Sequential",
        }
    }

    /// Inverse of [`AccessMode::name`] (used by the sweep memo cache).
    pub fn from_name(name: &str) -> Option<AccessMode> {
        AccessMode::ALL.into_iter().find(|m| m.name() == name)
    }
}

/// A concrete array organization for a given capacity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheOrg {
    /// Cache data capacity (bytes).
    pub capacity_bytes: u64,
    /// Number of banks (independently addressable).
    pub banks: u32,
    /// Mats per bank (each mat = 2x2 subarrays).
    pub mats_per_bank: u32,
    /// Rows per subarray (wordlines).
    pub rows: u32,
    /// Columns per subarray (bitline pairs).
    pub cols: u32,
    /// Column mux degree (bitlines sharing one sense amp).
    pub mux: u32,
    /// Access mode.
    pub mode: AccessMode,
}

impl CacheOrg {
    /// Subarrays in the whole cache.
    pub fn subarrays(&self) -> u64 {
        self.banks as u64 * self.mats_per_bank as u64 * 4
    }

    /// Data bits stored.
    pub fn data_bits(&self) -> u64 {
        self.capacity_bytes * 8
    }

    /// Bits per subarray.
    pub fn subarray_bits(&self) -> u64 {
        self.rows as u64 * self.cols as u64
    }

    /// Whether this organization exactly holds the capacity.
    pub fn is_consistent(&self) -> bool {
        self.subarrays() * self.subarray_bits() == self.data_bits()
            && self.cols % self.mux == 0
            && (self.cols / self.mux) as usize >= SECTOR_BYTES * 8
    }

    /// Sense amps per subarray.
    pub fn senseamps_per_subarray(&self) -> u32 {
        self.cols / self.mux
    }

    /// Enumerate all consistent organizations for a capacity (bytes)
    /// under one access mode. The geometry grid matches NVSim's default
    /// sweep ranges.
    pub fn enumerate(capacity_bytes: u64, mode: AccessMode) -> Vec<CacheOrg> {
        let mut out = Vec::new();
        let bits = capacity_bytes * 8;
        for bank_exp in 0..=5 {
            let banks = 1u32 << bank_exp;
            for rows in [128u32, 256, 512, 1024] {
                for cols in [512u32, 1024, 2048, 4096] {
                    let sub_bits = rows as u64 * cols as u64;
                    let total_subs = bits / sub_bits;
                    if total_subs == 0 || bits % sub_bits != 0 {
                        continue;
                    }
                    if total_subs % (banks as u64 * 4) != 0 {
                        continue;
                    }
                    let mats = (total_subs / (banks as u64 * 4)) as u32;
                    if mats == 0 || mats > 512 {
                        continue;
                    }
                    for mux in [1u32, 2, 4, 8] {
                        let org = CacheOrg {
                            capacity_bytes,
                            banks,
                            mats_per_bank: mats,
                            rows,
                            cols,
                            mux,
                            mode,
                        };
                        if org.is_consistent() {
                            out.push(org);
                        }
                    }
                }
            }
        }
        out
    }

    /// Tag array bits for the whole cache.
    pub fn tag_bits(&self) -> u64 {
        (self.capacity_bytes / LINE_BYTES as u64) * TAG_BITS_PER_LINE as u64
    }

    pub fn describe(&self) -> String {
        format!(
            "{}MB {}b x {}m x (2x2) x {}r x {}c mux{} {}",
            self.capacity_bytes / (1024 * 1024),
            self.banks,
            self.mats_per_bank,
            self.rows,
            self.cols,
            self.mux,
            self.mode.name()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    const MB: u64 = 1024 * 1024;

    #[test]
    fn enumerate_3mb_nonempty_and_consistent() {
        let orgs = CacheOrg::enumerate(3 * MB, AccessMode::Normal);
        assert!(orgs.len() > 10, "only {} orgs", orgs.len());
        for o in &orgs {
            assert!(o.is_consistent(), "{o:?}");
            assert_eq!(
                o.subarrays() * o.subarray_bits(),
                3 * MB * 8,
                "capacity mismatch {o:?}"
            );
        }
    }

    #[test]
    fn enumerate_covers_paper_capacities() {
        // Algorithm 1's capacity set plus the iso-area points (7/10 MB).
        for mb in [1u64, 2, 3, 4, 7, 8, 10, 16, 24, 32] {
            let orgs = CacheOrg::enumerate(mb * MB, AccessMode::Normal);
            assert!(!orgs.is_empty(), "no org for {mb} MB");
        }
    }

    #[test]
    fn sector_width_constraint_enforced() {
        for o in CacheOrg::enumerate(MB, AccessMode::Fast) {
            assert!(o.senseamps_per_subarray() as usize >= SECTOR_BYTES * 8);
        }
    }

    #[test]
    fn prop_enumerated_orgs_always_hold_capacity() {
        proptest::check(50, |g| {
            let mb = *g.choose(&[1u64, 2, 3, 4, 6, 7, 8, 10, 12, 16, 24, 32]);
            let mode = *g.choose(&AccessMode::ALL);
            for o in CacheOrg::enumerate(mb * MB, mode) {
                assert!(o.is_consistent());
                assert_eq!(o.data_bits(), mb * MB * 8);
            }
        });
    }

    #[test]
    fn tag_bits_proportional_to_lines() {
        let o = &CacheOrg::enumerate(3 * MB, AccessMode::Normal)[0];
        assert_eq!(o.tag_bits(), (3 * MB / 128) * 24);
    }
}
