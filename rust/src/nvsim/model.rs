//! The analytical cache PPA model: latency, dynamic energy, leakage and
//! area for one concrete [`CacheOrg`] in one memory technology.
//!
//! All structural scaling comes from geometry: bitcell dimensions set
//! subarray width/height, which set wordline/bitline RC and — through
//! the total die area — the H-tree distance. This is the mechanism that
//! makes MRAM caches *faster and cheaper than SRAM at large
//! capacities* (Fig. 9): the 3x-denser cells keep the wires short,
//! while SRAM's leakage grows with every cell. A small set of per-
//! technology periphery constants (see [`PeriphCal`]) is calibrated so
//! the 3 MB points land on the paper's Table II; everything else
//! (capacity scaling, mode/mux effects) emerges from structure.

use crate::device::MemTech;

use super::org::{AccessMode, CacheOrg, SECTOR_BYTES};
use super::tech::{Bitcell, TechParams};

/// Bits moved per L2 transaction (32 B sector).
pub const SECTOR_BITS: f64 = (SECTOR_BYTES * 8) as f64;
/// Address + control bits on the request path.
const ADDR_BITS: f64 = 40.0;

/// Per-technology periphery calibration ("the internal technology file"
/// knobs). Physical meaning:
/// * `read_path_epb` — array-level read energy per sensed bit: bitline
///   precharge/restore for SRAM; read-bias current, reference path and
///   current-mode sense amp for MRAM (dominates — MTJ sensing drives
///   ~30-50 uA through the stack for the whole window).
/// * `senseamp_leak` — static power of one sense amp (current-mode
///   MRAM amps idle at a bias current; SRAM latches don't).
/// * `write_driver_epb` — array-level write-path energy per written bit
///   over and above the cell's intrinsic switching energy.
#[derive(Clone, Copy, Debug)]
pub struct PeriphCal {
    pub read_path_epb: f64,
    pub write_driver_epb: f64,
    pub senseamp_leak: f64,
    /// Leakage density of the peripheral area (W/m^2): decoders, mux,
    /// drivers, control. MRAM periphery uses HP (leaky) devices to
    /// drive write currents; SRAM periphery can be HD.
    pub periph_leak_density: f64,
    /// Extra sensing latency beyond the bitcell development time:
    /// reference generation + a second sensing phase for low-TMR
    /// windows (SOT's dedicated small read device reads slowly —
    /// Table II: SOT read is the slowest at iso-capacity).
    pub sense_extra_latency: f64,
}

impl PeriphCal {
    /// The paper's 16 nm periphery calibration.
    pub fn for_tech(tech: MemTech) -> Self {
        Self::for_tech_at(tech, 16).expect("16 nm is calibrated")
    }

    /// Periphery calibration at an explicit node: the 16 nm table
    /// scaled by first-order deep-scaling factors — dynamic energy
    /// falls with CV^2, sensing tracks the faster devices, and leakage
    /// *density* rises as more (leakier) transistors pack each mm^2.
    /// Every factor comes from the device layer's
    /// [`crate::device::NodeScale`] (the single per-node factor
    /// table). 16 nm applies identity factors, so the paper numbers
    /// are reproduced bit for bit.
    pub fn for_tech_at(
        tech: MemTech,
        node_nm: u32,
    ) -> Result<Self, crate::device::UncalibratedNode> {
        let s = crate::device::NodeScale::at(node_nm)?;
        let base = Self::base_16nm(tech);
        Ok(PeriphCal {
            read_path_epb: base.read_path_epb * s.energy,
            write_driver_epb: base.write_driver_epb * s.energy,
            senseamp_leak: base.senseamp_leak,
            periph_leak_density: base.periph_leak_density * s.periph_leak_density,
            sense_extra_latency: base.sense_extra_latency * s.latency,
        })
    }

    fn base_16nm(tech: MemTech) -> Self {
        match tech {
            MemTech::Sram => PeriphCal {
                read_path_epb: 0.12e-12,
                write_driver_epb: 0.30e-12,
                senseamp_leak: 1.6e-6,
                periph_leak_density: 0.45e6,
                sense_extra_latency: 0.0,
            },
            MemTech::SttMram => PeriphCal {
                read_path_epb: 2.35e-12,
                write_driver_epb: 0.12e-12,
                senseamp_leak: 15e-6,
                periph_leak_density: 0.40e6,
                sense_extra_latency: 0.0,
            },
            MemTech::SotMram => PeriphCal {
                read_path_epb: 1.05e-12,
                write_driver_epb: 0.20e-12,
                senseamp_leak: 11e-6,
                periph_leak_density: 0.22e6,
                sense_extra_latency: 1.10e-9,
            },
        }
    }
}

/// Layout constants for peripheral strips (meters) — absolute, so the
/// *relative* periphery overhead grows as cells shrink, which is why
/// MRAM caches have lower array efficiency than SRAM at equal
/// organization (Table II: SRAM 5.53 mm^2 vs cells 1.86 mm^2).
mod strip {
    /// Column periphery height per subarray (sense amps, write drivers,
    /// column mux, precharge, ECC).
    pub const COL_PERIPH_H: f64 = 150e-6;
    /// Row periphery width per subarray (decoder + WL drivers).
    pub const ROW_PERIPH_W: f64 = 45e-6;
    /// Mat-level control overhead factor.
    pub const MAT_CTRL: f64 = 1.18;
    /// Bank routing / H-tree area factor.
    pub const BANK_ROUTE: f64 = 1.22;
}

/// Pipeline/control overhead added to every access (bank arbitration,
/// request queue, ECC) — constant per the 1080 Ti-class L2 front end.
const T_FIXED: f64 = 0.55e-9;

/// The PPA result for one cache design (per 32-byte-sector access).
#[derive(Clone, Copy, Debug)]
pub struct CachePpa {
    pub read_latency: f64,
    pub write_latency: f64,
    pub read_energy: f64,
    pub write_energy: f64,
    pub leakage_power: f64,
    pub area: f64,
}

impl CachePpa {
    /// EDAP figure of merit (Algorithm 1's `calculate(EDAP)`): mean
    /// access energy x mean latency x area. Leakage enters through a
    /// duty-cycle charge (leakage power x mean latency) so low-leakage
    /// designs win ties, as in NVSim's combined objective.
    pub fn edap(&self) -> f64 {
        let lat = 0.5 * (self.read_latency + self.write_latency);
        let en = 0.5 * (self.read_energy + self.write_energy)
            + self.leakage_power * lat;
        en * lat * self.area
    }
}

/// A fully-specified design: organization + technology + derived PPA.
#[derive(Clone, Copy, Debug)]
pub struct CacheDesign {
    pub tech: MemTech,
    pub org: CacheOrg,
    pub ppa: CachePpa,
}

/// Geometry of one subarray in meters.
struct SubGeom {
    width: f64,
    height: f64,
}

fn subarray_geom(cell: &Bitcell, org: &CacheOrg) -> SubGeom {
    SubGeom {
        width: org.cols as f64 * cell.width,
        height: org.rows as f64 * cell.height,
    }
}

/// Evaluate the PPA of `org` built from `cell` under `tech`.
pub fn evaluate(tech: &TechParams, cell: &Bitcell, org: &CacheOrg) -> CachePpa {
    let g = subarray_geom(cell, org);
    let cal = PeriphCal::for_tech_at(cell.params.tech, tech.node_nm)
        .expect("TechParams only exist for calibrated nodes");

    // ---------- area ------------------------------------------------
    // Peripheral strip silicon shrinks with the node's layout pitch.
    let row_periph_w = strip::ROW_PERIPH_W * tech.periph_scale;
    let col_periph_h = strip::COL_PERIPH_H * tech.periph_scale;
    let sub_cells = g.width * g.height;
    let sub_area = (g.width + row_periph_w) * (g.height + col_periph_h);
    let mat_area = 4.0 * sub_area * strip::MAT_CTRL;
    let bank_area = org.mats_per_bank as f64 * mat_area * strip::BANK_ROUTE;
    // tag array: modeled as SRAM regardless of data technology (tags
    // are latency-critical and tiny), 50% periphery overhead — sized
    // from the ACTIVE node's SRAM cell, so iso-area comparisons stay
    // honest at 7/5 nm.
    let tag_area = org.tag_bits() as f64 * tech.sram_cell_area * 1.5;
    let area = org.banks as f64 * bank_area + tag_area;
    let _ = sub_cells;

    // ---------- wire segments ---------------------------------------
    // H-tree: to the target bank center, then to the mat. Distances
    // scale with the physical footprint.
    let d_htree = 0.5 * area.sqrt() + 0.5 * bank_area.sqrt();
    let t_htree = tech.t_wire_global * d_htree;
    let e_htree_per_bit = tech.e_wire_global * d_htree;

    // ---------- decoder ---------------------------------------------
    let dec_stages = (org.rows as f64).log2().ceil().max(1.0);
    let t_dec = dec_stages * 2.0 * tech.t_fo4;
    let e_dec = dec_stages * 16.0 * tech.e_dec_stage;

    // ---------- wordline --------------------------------------------
    // Fast mode segments the wordline and only fires the needed slice.
    let active_frac = match org.mode {
        AccessMode::Fast => {
            ((SECTOR_BITS * org.mux as f64) / org.cols as f64).min(1.0)
        }
        _ => 1.0,
    };
    let wl_len = g.width * active_frac;
    let r_wl = tech.r_wire_local * wl_len;
    let c_wl = tech.c_wire_local * wl_len
        + org.cols as f64 * active_frac * tech.c_cell_gate;
    let t_wl = 0.38 * r_wl * c_wl;
    let e_wl = c_wl * tech.vdd * tech.vdd;

    // ---------- bitline + sensing -----------------------------------
    let r_bl = tech.r_wire_local * g.height;
    let c_bl = tech.c_wire_local * g.height
        + org.rows as f64 * tech.c_cell_drain;
    let t_bl =
        0.38 * r_bl * c_bl + cell.sense_development() + cal.sense_extra_latency;
    let sensed_bits = match org.mode {
        AccessMode::Normal => (org.cols / org.mux) as f64,
        AccessMode::Fast => SECTOR_BITS,
        // Sequential reads only the matching way's sector.
        AccessMode::Sequential => SECTOR_BITS,
    };
    let e_sense = sensed_bits * cal.read_path_epb;

    // ---------- column mux + output ---------------------------------
    let t_mux = ((org.mux as f64).log2() + 1.0) * 2.0 * tech.t_fo4;

    // ---------- tag path --------------------------------------------
    // Tag array is small: model its access as a fraction of the data
    // array path plus a fixed comparator term.
    let t_tag = 0.30 * (t_dec + t_wl + t_bl) + 0.20e-9;

    // ---------- compose read ----------------------------------------
    let t_array = t_dec + t_wl + t_bl + t_mux;
    let (t_read, mode_read_energy_factor) = match org.mode {
        // tag and data in parallel; data gated by tag compare
        AccessMode::Normal => (t_array.max(t_tag), 1.0),
        // everything overfetched in parallel, fastest
        AccessMode::Fast => (t_array.max(t_tag) * 0.92, 1.25),
        // tag first, then data: serial
        AccessMode::Sequential => (t_tag + t_array, 0.85),
    };
    let read_latency = T_FIXED + t_htree + t_read + t_htree;
    let read_energy = (e_htree_per_bit * (SECTOR_BITS + ADDR_BITS)
        + e_dec
        + e_wl
        + e_sense)
        * mode_read_energy_factor;

    // ---------- compose write ---------------------------------------
    // Writes are posted: they skip the front-end pipeline stall
    // (T_FIXED) and the return H-tree trip. The cell switching time
    // dominates for STT.
    let cell_write = cell.params.write_latency();
    let t_bl_write = 0.69 * r_bl * c_bl;
    let write_latency = t_htree + t_dec + t_wl + t_bl_write + cell_write;
    let written_bits = SECTOR_BITS;
    let write_energy = e_htree_per_bit * (SECTOR_BITS + ADDR_BITS)
        + e_dec
        + e_wl
        + written_bits
            * (cell.params.write_energy() + cal.write_driver_epb)
        + c_bl * tech.vdd * tech.vdd * written_bits * 0.5;

    // ---------- leakage ---------------------------------------------
    let n_cells = org.data_bits() as f64;
    let cell_leak = n_cells * cell.params.cell_leakage;
    let n_subarrays = org.subarrays() as f64;
    let n_senseamps = n_subarrays * org.senseamps_per_subarray() as f64;
    // peripheral silicon = everything that is not cells or tags
    let cell_area_total = n_cells * cell.area;
    let periph_area = (area - cell_area_total - tag_area).max(0.0);
    let periph_leak = n_senseamps * cal.senseamp_leak
        + periph_area * cal.periph_leak_density
        + n_subarrays * org.rows as f64 * tech.leak_row_driver
        + (org.banks * org.mats_per_bank) as f64 * tech.leak_mat_ctrl
        + tech.leak_wire_global
            * d_htree
            * (SECTOR_BITS + ADDR_BITS)
            * org.banks as f64;
    // tag array leaks like SRAM always — at the active node's per-cell
    // leakage (deeply-scaled 6T cells leak more)
    let tag_leak = org.tag_bits() as f64
        * crate::device::BitcellParams::paper_at(MemTech::Sram, tech.node_nm)
            .expect("TechParams only exist for calibrated nodes")
            .cell_leakage;
    let leakage_power = cell_leak + periph_leak + tag_leak;

    CachePpa {
        read_latency,
        write_latency,
        read_energy,
        write_energy,
        leakage_power,
        area,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nvsim::org::AccessMode;
    use crate::util::proptest;

    const MB: u64 = 1024 * 1024;

    fn eval_first(tech_mem: MemTech, mb: u64, mode: AccessMode) -> CachePpa {
        let tech = TechParams::n16();
        let cell = Bitcell::paper(tech_mem);
        let orgs = CacheOrg::enumerate(mb * MB, mode);
        evaluate(&tech, &cell, &orgs[orgs.len() / 2])
    }

    #[test]
    fn all_quantities_positive_and_sane() {
        proptest::check(60, |g| {
            let mem = *g.choose(&MemTech::ALL);
            let mb = *g.choose(&[1u64, 2, 3, 4, 8, 16, 32]);
            let mode = *g.choose(&AccessMode::ALL);
            let node = *g.choose(&crate::device::CALIBRATED_NODES_NM);
            let tech = TechParams::at(node).unwrap();
            let cell = Bitcell::at(mem, node).unwrap();
            let orgs = CacheOrg::enumerate(mb * MB, mode);
            let org = g.choose(&orgs);
            let p = evaluate(&tech, &cell, org);
            assert!(p.read_latency > 0.0 && p.read_latency < 100e-9);
            assert!(p.write_latency > 0.0 && p.write_latency < 100e-9);
            assert!(p.read_energy > 0.0 && p.read_energy < 100e-9);
            assert!(p.write_energy > 0.0 && p.write_energy < 100e-9);
            assert!(p.leakage_power > 0.0 && p.leakage_power < 1000.0);
            assert!(p.area > 0.0 && p.area < 1e-2, "area {}", p.area);
            assert!(p.edap() > 0.0);
        });
    }

    #[test]
    fn sram_leaks_more_than_mram() {
        let s = eval_first(MemTech::Sram, 3, AccessMode::Normal);
        let t = eval_first(MemTech::SttMram, 3, AccessMode::Normal);
        let o = eval_first(MemTech::SotMram, 3, AccessMode::Normal);
        assert!(s.leakage_power > 3.0 * t.leakage_power);
        assert!(s.leakage_power > 3.0 * o.leakage_power);
    }

    #[test]
    fn stt_write_latency_dominated_by_cell() {
        let t = eval_first(MemTech::SttMram, 3, AccessMode::Normal);
        assert!(t.write_latency > 8e-9, "{}", t.write_latency);
        // EDAP-tuned SRAM avoids the monster-wordline organizations.
        let s = crate::nvsim::explorer::tuned_cache(MemTech::Sram, 3 * MB);
        assert!(s.ppa.write_latency < 3e-9, "{}", s.ppa.write_latency);
    }

    #[test]
    fn mram_denser_than_sram_iso_capacity() {
        let s = eval_first(MemTech::Sram, 3, AccessMode::Normal);
        let t = eval_first(MemTech::SttMram, 3, AccessMode::Normal);
        assert!(t.area < 0.6 * s.area, "stt {} sram {}", t.area, s.area);
    }

    #[test]
    fn sequential_mode_slower_but_cheaper_reads() {
        let n = eval_first(MemTech::Sram, 3, AccessMode::Normal);
        let q = eval_first(MemTech::Sram, 3, AccessMode::Sequential);
        assert!(q.read_latency > n.read_latency);
        assert!(q.read_energy < n.read_energy);
    }

    #[test]
    fn leakage_scales_with_capacity() {
        let a = eval_first(MemTech::Sram, 2, AccessMode::Normal);
        let b = eval_first(MemTech::Sram, 16, AccessMode::Normal);
        let ratio = b.leakage_power / a.leakage_power;
        assert!((4.0..16.0).contains(&ratio), "ratio {ratio}");
    }

    fn eval_at(node: u32, mem: MemTech, mb: u64) -> CachePpa {
        let tech = TechParams::at(node).unwrap();
        let cell = Bitcell::at(mem, node).unwrap();
        let orgs = CacheOrg::enumerate(mb * MB, AccessMode::Normal);
        evaluate(&tech, &cell, &orgs[orgs.len() / 2])
    }

    #[test]
    fn deep_nodes_shrink_area_and_energy_but_sram_leaks_more() {
        for mem in MemTech::ALL {
            let p16 = eval_at(16, mem, 3);
            let p7 = eval_at(7, mem, 3);
            let p5 = eval_at(5, mem, 3);
            assert!(p7.area < p16.area, "{mem} area must shrink at 7nm");
            assert!(p5.area < p7.area, "{mem} area must shrink at 5nm");
            assert!(p7.read_energy < p16.read_energy, "{mem} reads get cheaper");
        }
        // the scalability story: the same SRAM cache leaks MORE at the
        // deep node, while the MTJ arrays hold the line — the relative
        // NVM leakage advantage widens
        let sram16 = eval_at(16, MemTech::Sram, 3);
        let sram7 = eval_at(7, MemTech::Sram, 3);
        let stt16 = eval_at(16, MemTech::SttMram, 3);
        let stt7 = eval_at(7, MemTech::SttMram, 3);
        assert!(sram7.leakage_power > sram16.leakage_power);
        assert!(
            sram7.leakage_power / stt7.leakage_power
                > sram16.leakage_power / stt16.leakage_power,
            "NVM leakage advantage must widen at 7nm: {} vs {}",
            sram7.leakage_power / stt7.leakage_power,
            sram16.leakage_power / stt16.leakage_power
        );
    }

    #[test]
    fn tag_array_uses_the_active_nodes_sram_cell() {
        // Same org: only the node differs. The tag contribution must
        // scale with the node's SRAM cell, so the 7 nm design's area is
        // strictly below a hybrid that kept the 16 nm tag constant.
        let org = CacheOrg::enumerate(3 * MB, AccessMode::Normal)[0];
        let n7 = TechParams::n7();
        let p7 = evaluate(&n7, &Bitcell::at(MemTech::SttMram, 7).unwrap(), &org);
        let tag7 = org.tag_bits() as f64 * n7.sram_cell_area * 1.5;
        let tag16 = org.tag_bits() as f64 * TechParams::n16().sram_cell_area * 1.5;
        assert!(tag7 < tag16);
        assert!(p7.area > tag7, "tag array is part of the total");
    }
}
