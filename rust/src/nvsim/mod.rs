//! Microarchitecture-level cache PPA modeling (paper §III-B) — an
//! NVSim-class analytical model (Dong et al., TCAD'12) reimplemented
//! from scratch and driven by the device layer's bitcell parameters.
//!
//! Model structure (mirrors NVSim):
//!
//! ```text
//! cache = banks x [ mats x [ 2x2 subarrays ] ]  + H-tree + tag arrays
//! subarray = rows x cols bitcell grid
//!          + row decoder + wordline drivers        (RC + Horowitz)
//!          + bitline columns + column mux + sense  (RC + device sense)
//!          + write drivers
//! ```
//!
//! Latency = H-tree in + decode + wordline + bitline/sense (+ cell
//! write time) + H-tree out; energy sums switched capacitance along the
//! same path plus the per-bit cell energies; leakage = per-cell (SRAM
//! only — MTJs do not leak) + periphery proportional to component
//! count; area composes cell grids with per-subarray peripheral
//! overheads and H-tree wiring.
//!
//! [`explorer`] implements the paper's Algorithm 1: for every memory
//! technology and capacity, enumerate all organizations x optimization
//! targets x access modes and keep the EDAP-optimal configuration.
//! Calibration against the paper's published Table II (3 MB / iso-area
//! points) is asserted in `rust/tests/nvsim_calibration.rs`.

pub mod explorer;
pub mod hybrid;
pub mod model;
pub mod org;
pub mod tech;

pub use explorer::{explore, tuned_cache, tuned_cache_at, OptTarget, TunedConfig};
pub use hybrid::{compose_ppa, hybrid_at, HybridDesign, HybridSel, TechSel};
pub use model::{CacheDesign, CachePpa};
pub use org::{AccessMode, CacheOrg};
pub use tech::TechParams;
