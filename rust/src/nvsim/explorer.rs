//! Algorithm 1 — EDAP-optimal cache tuning.
//!
//! ```text
//! for mem in {SRAM, STT, SOT}:
//!   for cap in {1, 2, 4, 8, 16, 32} (+ 3/7/10/24 for the studies):
//!     for opt in {RdLat, WrLat, RdEn, WrEn, RdEDP, WrEDP, Area, Leak}:
//!       for acc in {Normal, Fast, Sequential}:
//!         Q = calculate(EDAP); keep argmin
//! ```
//!
//! The optimization target is NVSim's peripheral-sizing objective: it
//! biases how decoders, sense amps, drivers and repeaters are sized
//! before the organization is evaluated. We abstract that sizing to
//! first-order PPA trade-off profiles (each target helps its metric and
//! taxes the others — no free lunch), then enumerate *all* consistent
//! organizations under each (target, mode) pair and keep the
//! min-EDAP design, exactly as Algorithm 1 does.

use crate::device::MemTech;

use super::model::{evaluate, CacheDesign, CachePpa};
use super::org::{AccessMode, CacheOrg};
use super::tech::{Bitcell, TechParams};

/// NVSim optimization targets (Algorithm 1's set O).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OptTarget {
    ReadLatency,
    WriteLatency,
    ReadEnergy,
    WriteEnergy,
    ReadEdp,
    WriteEdp,
    Area,
    Leakage,
}

impl OptTarget {
    pub const ALL: [OptTarget; 8] = [
        OptTarget::ReadLatency,
        OptTarget::WriteLatency,
        OptTarget::ReadEnergy,
        OptTarget::WriteEnergy,
        OptTarget::ReadEdp,
        OptTarget::WriteEdp,
        OptTarget::Area,
        OptTarget::Leakage,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            OptTarget::ReadLatency => "ReadLatency",
            OptTarget::WriteLatency => "WriteLatency",
            OptTarget::ReadEnergy => "ReadEnergy",
            OptTarget::WriteEnergy => "WriteEnergy",
            OptTarget::ReadEdp => "ReadEDP",
            OptTarget::WriteEdp => "WriteEDP",
            OptTarget::Area => "Area",
            OptTarget::Leakage => "Leakage",
        }
    }

    /// Inverse of [`OptTarget::name`] (used by the sweep memo cache).
    pub fn from_name(name: &str) -> Option<OptTarget> {
        OptTarget::ALL.into_iter().find(|o| o.name() == name)
    }

    /// Apply the target's peripheral-sizing bias to a baseline PPA.
    /// Profiles are (read_lat, write_lat, read_en, write_en, leak, area)
    /// multipliers; each <1 entry is paid for by >1 entries elsewhere.
    pub fn apply(&self, p: &CachePpa) -> CachePpa {
        let m: [f64; 6] = match self {
            // bigger decoders/repeaters: faster reads, leakier, larger
            OptTarget::ReadLatency => [0.85, 0.97, 1.10, 1.05, 1.18, 1.08],
            // bigger write drivers
            OptTarget::WriteLatency => [0.98, 0.88, 1.04, 1.12, 1.10, 1.06],
            // small sense amps: slower, cheaper reads
            OptTarget::ReadEnergy => [1.12, 1.00, 0.82, 1.00, 0.95, 0.98],
            // weak write drivers
            OptTarget::WriteEnergy => [1.00, 1.12, 1.00, 0.82, 0.95, 0.98],
            // balanced read path
            OptTarget::ReadEdp => [0.92, 1.00, 0.92, 1.02, 1.05, 1.02],
            OptTarget::WriteEdp => [1.00, 0.92, 1.02, 0.92, 1.05, 1.02],
            // tight layout: slower wires
            OptTarget::Area => [1.10, 1.06, 1.02, 1.02, 1.00, 0.88],
            // high-Vt periphery: slower, less leaky (cells keep their
            // retention-constrained flavor, so the lever is bounded)
            OptTarget::Leakage => [1.15, 1.10, 1.02, 1.02, 0.88, 1.00],
        };
        CachePpa {
            read_latency: p.read_latency * m[0],
            write_latency: p.write_latency * m[1],
            read_energy: p.read_energy * m[2],
            write_energy: p.write_energy * m[3],
            leakage_power: p.leakage_power * m[4],
            area: p.area * m[5],
        }
    }
}

/// The tuned configuration Algorithm 1 appends per (mem, cap).
#[derive(Clone, Copy, Debug)]
pub struct TunedConfig {
    pub tech: MemTech,
    pub capacity_bytes: u64,
    pub org: CacheOrg,
    pub opt: OptTarget,
    pub ppa: CachePpa,
}

impl TunedConfig {
    pub fn design(&self) -> CacheDesign {
        CacheDesign { tech: self.tech, org: self.org, ppa: self.ppa }
    }
}

/// Evaluate every (org, opt, mode) for one memory + capacity on the
/// paper's 16 nm node and return the EDAP-optimal configuration.
pub fn tuned_cache(mem: MemTech, capacity_bytes: u64) -> TunedConfig {
    tuned_cache_at(mem, capacity_bytes, 16).expect("16 nm is calibrated")
}

/// As [`tuned_cache`] at an explicit process node: Algorithm 1 against
/// that node's interconnect parameters and bitcell geometry. Returns a
/// typed error for uncalibrated nodes, so untrusted node axes degrade
/// to an error response instead of a panic.
pub fn tuned_cache_at(
    mem: MemTech,
    capacity_bytes: u64,
    node_nm: u32,
) -> Result<TunedConfig, crate::device::UncalibratedNode> {
    let tech = TechParams::at(node_nm)?;
    let cell = Bitcell::at(mem, node_nm)?;
    let mut best: Option<TunedConfig> = None;
    for mode in AccessMode::ALL {
        for org in CacheOrg::enumerate(capacity_bytes, mode) {
            let base = evaluate(&tech, &cell, &org);
            for opt in OptTarget::ALL {
                let ppa = opt.apply(&base);
                let cand = TunedConfig {
                    tech: mem,
                    capacity_bytes,
                    org,
                    opt,
                    ppa,
                };
                let better = match &best {
                    None => true,
                    Some(b) => ppa.edap() < b.ppa.edap(),
                };
                if better {
                    best = Some(cand);
                }
            }
        }
    }
    Ok(best.expect("no consistent organization for capacity"))
}

/// Algorithm 1 over a capacity list: the `TunedConfig` table.
pub fn explore(capacities_mb: &[u64]) -> Vec<TunedConfig> {
    let mut out = Vec::new();
    for &mem in &MemTech::ALL {
        for &mb in capacities_mb {
            out.push(tuned_cache(mem, mb * 1024 * 1024));
        }
    }
    out
}

/// The paper's Algorithm 1 capacity set (MB).
pub const PAPER_CAPACITIES_MB: [u64; 6] = [1, 2, 4, 8, 16, 32];

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1024 * 1024;

    #[test]
    fn tuned_beats_or_equals_any_fixed_choice() {
        let best = tuned_cache(MemTech::SttMram, 2 * MB);
        // spot check against a handful of fixed configurations
        let tech = TechParams::n16();
        let cell = Bitcell::paper(MemTech::SttMram);
        for mode in AccessMode::ALL {
            for org in CacheOrg::enumerate(2 * MB, mode).into_iter().take(5) {
                let p = evaluate(&tech, &cell, &org);
                assert!(
                    best.ppa.edap() <= OptTarget::ReadEdp.apply(&p).edap() * 1.0001
                );
            }
        }
    }

    #[test]
    fn explore_covers_mem_x_capacity() {
        let t = explore(&[1, 2]);
        assert_eq!(t.len(), 6);
        // every (mem, cap) distinct
        for m in MemTech::ALL {
            for mb in [1u64, 2] {
                assert!(
                    t.iter().any(|c| c.tech == m
                        && c.capacity_bytes == mb * MB),
                    "missing {m} {mb}MB"
                );
            }
        }
    }

    #[test]
    fn opt_targets_trade_off_not_dominate() {
        // applying a target must improve its own metric and worsen at
        // least one other.
        let p = CachePpa {
            read_latency: 1e-9,
            write_latency: 1e-9,
            read_energy: 1e-10,
            write_energy: 1e-10,
            leakage_power: 1.0,
            area: 1e-6,
        };
        let r = OptTarget::ReadLatency.apply(&p);
        assert!(r.read_latency < p.read_latency);
        assert!(r.leakage_power > p.leakage_power);
        let l = OptTarget::Leakage.apply(&p);
        assert!(l.leakage_power < p.leakage_power);
        assert!(l.read_latency > p.read_latency);
    }

    #[test]
    fn larger_caches_have_larger_area_and_leakage() {
        for mem in MemTech::ALL {
            let small = tuned_cache(mem, 2 * MB);
            let large = tuned_cache(mem, 16 * MB);
            assert!(large.ppa.area > 2.0 * small.ppa.area, "{mem}");
            assert!(
                large.ppa.leakage_power > 2.0 * small.ppa.leakage_power,
                "{mem}"
            );
        }
    }

    #[test]
    fn tuned_cache_at_is_node_distinct() {
        // 16 nm through the node-aware entry point is the legacy solve
        let legacy = tuned_cache(MemTech::SttMram, 2 * MB);
        let at16 = tuned_cache_at(MemTech::SttMram, 2 * MB, 16).unwrap();
        assert_eq!(format!("{legacy:?}"), format!("{at16:?}"));

        // deep nodes tune to genuinely different designs — smaller
        // area at iso-capacity, never 16 nm aliasing
        for mem in MemTech::ALL {
            let n16 = tuned_cache_at(mem, 2 * MB, 16).unwrap();
            let n7 = tuned_cache_at(mem, 2 * MB, 7).unwrap();
            let n5 = tuned_cache_at(mem, 2 * MB, 5).unwrap();
            assert!(n7.ppa.area < n16.ppa.area, "{mem} 7nm must be denser");
            assert!(n5.ppa.area < n7.ppa.area, "{mem} 5nm must be denser");
            assert_ne!(
                format!("{:?}", n7.ppa),
                format!("{:?}", n16.ppa),
                "{mem} nodes must not alias"
            );
        }
        // uncalibrated nodes error instead of panicking
        assert!(tuned_cache_at(MemTech::Sram, 2 * MB, 9).is_err());
    }
}
