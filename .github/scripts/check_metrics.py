#!/usr/bin/env python3
"""Cross-check a /metrics scrape against a /memo/stats snapshot.

The serve-smoke CI step curls GET /metrics (Prometheus text) and then
GET /memo/stats (JSON) from the same server and passes both files here.
The gate asserts that the exposition is real telemetry, not a static
page: enough distinct series, a live request counter, and memo counters
that agree exactly with the server's own /memo/stats numbers (the
registry mirrors and the memo's per-instance atomics must never drift —
GET requests between the two scrapes change request counts but never
solve/eval/traffic counts, so those must match exactly).

Usage: check_metrics.py <metrics.txt> <stats.json>
"""

import json
import pathlib
import sys

if len(sys.argv) != 3:
    sys.exit("usage: check_metrics.py <metrics.txt> <stats.json>")

metrics_text = pathlib.Path(sys.argv[1]).read_text()
stats = json.loads(pathlib.Path(sys.argv[2]).read_text())
failures = []

# Parse the exposition: every non-comment line is `<series> <value>`.
series = {}
for line in metrics_text.splitlines():
    line = line.strip()
    if not line or line.startswith("#"):
        continue
    try:
        key, value = line.rsplit(None, 1)
        series[key] = float(value)
    except ValueError:
        failures.append(f"unparseable exposition line: {line!r}")

MIN_SERIES = 10
if len(series) < MIN_SERIES:
    failures.append(
        f"only {len(series)} series exposed (need >= {MIN_SERIES}); "
        "is the registry actually wired into the hot paths?"
    )

requests = series.get("deepnvm_http_requests_total")
if requests is None or requests <= 0:
    failures.append(
        f"deepnvm_http_requests_total is {requests!r} after live traffic"
    )

# The memo counters exposed by the registry must agree exactly with the
# per-instance counters /memo/stats reports (one memo per process).
for metric, stats_key in (
    ("deepnvm_circuit_solves_total", "solve_count"),
    ("deepnvm_point_evals_total", "eval_count"),
    ("deepnvm_memo_traffic_builds_total", "traffic_build_count"),
):
    got = series.get(metric)
    want = stats.get(stats_key)
    if got is None:
        failures.append(f"{metric} missing from the exposition")
    elif want is None:
        failures.append(f"{stats_key} missing from /memo/stats")
    elif got != want:
        failures.append(
            f"{metric} {got} != /memo/stats {stats_key} {want} "
            "(the same event is counted in two places)"
        )

if series.get("deepnvm_circuit_solves_total", 0) <= 0:
    failures.append(
        "deepnvm_circuit_solves_total is 0 — the smoke traffic must "
        "have forced at least one circuit solve"
    )

# /memo/stats was scraped after /metrics on the same server, so its
# request counter can only be larger.
stats_requests = stats.get("requests")
if stats_requests is None:
    failures.append("/memo/stats has no 'requests' key")
elif requests is not None and stats_requests < requests:
    failures.append(
        f"/memo/stats requests {stats_requests} < /metrics "
        f"deepnvm_http_requests_total {requests} (scraped later, on the "
        "same server — the counter went backwards)"
    )

if failures:
    print("metrics consistency FAILED:")
    for f in failures:
        print(f"  - {f}")
    sys.exit(1)
print(f"metrics consistency OK ({len(series)} series)")
