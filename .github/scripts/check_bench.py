#!/usr/bin/env python3
"""Gate CI on the recorded bench baselines.

Parses BENCH_sweep.json / BENCH_serve.json / BENCH_distributed.json —
freshly rewritten by the bench-smoke step — and fails when a recorded
value crosses the acceptance thresholds the files themselves carry.
Null timings mean the bench did not actually run; that is a failure
here, not a skip, because this gate is what keeps the perf trajectory
honest (the committed baselines start null only in environments with
no Rust toolchain — CI is not one of them).

Usage: check_bench.py [dir-containing-the-BENCH-files]
"""

import json
import pathlib
import sys

root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".")
failures = []


def load(name):
    path = root / name
    if not path.exists():
        failures.append(f"{name}: missing (did the bench smoke step run?)")
        return None
    try:
        return json.loads(path.read_text())
    except ValueError as e:
        failures.append(f"{name}: unparseable ({e})")
        return None


def recorded(doc, name, key):
    value = doc.get(key)
    if value is None:
        failures.append(f"{name}: '{key}' was not recorded (bench did not run?)")
    return value


def ratio_gate(name, doc, fast_key, slow_key, tolerance=1.0, why=""):
    """Require doc[fast_key] < doc[slow_key] * tolerance.

    Both values must have been recorded (null timings already fail via
    recorded()); the gate itself only fires when both are numbers, so
    a single missing field produces one clear failure, not two.
    """
    fast = recorded(doc, name, fast_key)
    slow = recorded(doc, name, slow_key)
    if fast is None or slow is None:
        return
    if fast >= slow * tolerance:
        bound = f"{slow_key} * {tolerance}" if tolerance != 1.0 else slow_key
        failures.append(
            f"{name}: {fast_key} {fast:.3f} ms >= {bound} "
            f"({slow * tolerance:.3f} ms){' — ' + why if why else ''}"
        )


sweep = load("BENCH_sweep.json")
if sweep is not None:
    acc = sweep.get("acceptance", {})
    speedup = recorded(sweep, "BENCH_sweep.json", "parallel_speedup")
    floor = acc.get("parallel_speedup_min")
    if speedup is not None and floor is not None and speedup < floor:
        failures.append(
            f"BENCH_sweep.json: parallel_speedup {speedup:.2f} < required {floor}"
        )
    solves = recorded(sweep, "BENCH_sweep.json", "warm_rerun_circuit_solves")
    ceiling = acc.get("warm_rerun_circuit_solves_max", 0)
    if solves is not None and solves > ceiling:
        failures.append(
            "BENCH_sweep.json: warm_rerun_circuit_solves "
            f"{solves} > allowed {ceiling}"
        )
    # the cross-node sweep (16/7/5 nm) must also replay warm with zero
    # circuit solves: per-node CircuitKeys, no 16 nm aliasing
    node_solves = recorded(
        sweep, "BENCH_sweep.json", "node_sweep_warm_rerun_circuit_solves"
    )
    node_ceiling = acc.get("node_sweep_warm_rerun_circuit_solves_max", 0)
    if node_solves is not None and node_solves > node_ceiling:
        failures.append(
            "BENCH_sweep.json: node_sweep_warm_rerun_circuit_solves "
            f"{node_solves} > allowed {node_ceiling}"
        )
    nodes = recorded(sweep, "BENCH_sweep.json", "node_sweep_nodes")
    if nodes is not None and nodes < 3:
        failures.append(
            f"BENCH_sweep.json: node_sweep_nodes {nodes} < 3 "
            "(the bench must cover 16/7/5 nm)"
        )
    # batch axis: traffic-coefficient builds are bounded by the number
    # of (dnn, phase) pairs, NEVER by the batch count — the closed-form
    # BatchLine engine's contract
    traffic_evals = recorded(
        sweep, "BENCH_sweep.json", "batch_sweep_traffic_evals"
    )
    traffic_ceiling = acc.get("batch_sweep_traffic_evals_max")
    if (
        traffic_evals is not None
        and traffic_ceiling is not None
        and traffic_evals > traffic_ceiling
    ):
        failures.append(
            "BENCH_sweep.json: batch_sweep_traffic_evals "
            f"{traffic_evals} > allowed {traffic_ceiling} "
            "(one traffic build per (dnn, phase))"
        )
    warm_traffic = recorded(
        sweep, "BENCH_sweep.json", "batch_sweep_warm_rerun_traffic_evals"
    )
    warm_traffic_ceiling = acc.get("batch_sweep_warm_rerun_traffic_evals_max", 0)
    if warm_traffic is not None and warm_traffic > warm_traffic_ceiling:
        failures.append(
            "BENCH_sweep.json: batch_sweep_warm_rerun_traffic_evals "
            f"{warm_traffic} > allowed {warm_traffic_ceiling}"
        )
    batches = recorded(sweep, "BENCH_sweep.json", "batch_sweep_batches")
    if batches is not None and batches < 16:
        failures.append(
            f"BENCH_sweep.json: batch_sweep_batches {batches} < 16 "
            "(the batch sweep must be wide enough to prove the axis is free)"
        )
    # Timing fields are now sourced from the obs histograms; they must
    # be recorded (non-null) and the memoized paths must actually win.
    recorded(sweep, "BENCH_sweep.json", "parallel_ms")
    ratio_gate(
        "BENCH_sweep.json", sweep, "warm_ms", "serial_ms",
        why="a warm rerun must beat the cold serial sweep",
    )
    ratio_gate(
        "BENCH_sweep.json", sweep, "node_sweep_warm_ms", "node_sweep_cold_ms",
        why="the warm node sweep must beat its cold run",
    )
    ratio_gate(
        "BENCH_sweep.json", sweep, "batch_sweep_warm_ms", "batch_sweep_cold_ms",
        why="the warm batch sweep must beat its cold run",
    )

serve = load("BENCH_serve.json")
if serve is not None:
    cold = recorded(serve, "BENCH_serve.json", "cold_solve_ms")
    warm = recorded(serve, "BENCH_serve.json", "warm_solve_ms")
    if cold is not None and warm is not None and warm >= cold:
        failures.append(
            f"BENCH_serve.json: warm_solve_ms {warm:.3f} >= cold_solve_ms "
            f"{cold:.3f} (the memo hit must beat the cold solve)"
        )
    # Keep-alive reuses one pooled connection; it must not lose to the
    # connect-per-request path (tolerance absorbs scheduler noise on
    # sub-millisecond loopback calls).
    ratio_gate(
        "BENCH_serve.json", serve, "warm_solve_keepalive_ms", "warm_solve_ms",
        tolerance=1.25,
        why="pooled keep-alive calls must not be slower than one-shot",
    )

dist = load("BENCH_distributed.json")
if dist is not None:
    acc = dist.get("acceptance", {})
    for key, cap_key in (
        ("replay_solves", "replay_solves_max"),
        ("replay_evals", "replay_evals_max"),
    ):
        value = recorded(dist, "BENCH_distributed.json", key)
        ceiling = acc.get(cap_key, 0)
        if value is not None and value > ceiling:
            failures.append(
                f"BENCH_distributed.json: {key} {value} > allowed {ceiling} "
                "(the merged shard union must cover the full grid)"
            )
    recorded(dist, "BENCH_distributed.json", "single_ms")
    recorded(dist, "BENCH_distributed.json", "distributed_ms")
    retries = recorded(dist, "BENCH_distributed.json", "dispatch_retries")
    if retries is not None and retries > 0:
        failures.append(
            f"BENCH_distributed.json: dispatch_retries {retries} > 0 "
            "(loopback workers must not shed shards)"
        )

if failures:
    print("bench acceptance FAILED:")
    for f in failures:
        print(f"  - {f}")
    sys.exit(1)
print("bench acceptance OK")
