#!/usr/bin/env python3
"""Gate CI on the recorded bench baselines.

Parses BENCH_sweep.json / BENCH_serve.json / BENCH_distributed.json —
freshly rewritten by the bench-smoke step — and fails when a recorded
value crosses the acceptance thresholds the files themselves carry.
Null timings mean the bench did not actually run; that is a failure
here, not a skip, because this gate is what keeps the perf trajectory
honest (the committed baselines start null only in environments with
no Rust toolchain — CI is not one of them).

Beyond the absolute acceptance thresholds, every BENCH file is also
trend-gated against its committed BASELINE_*.json: a recorded timing
greater than trend_tolerance (default 1.5) times the committed
baseline fails the build. Null baseline entries mean no baseline has
been promoted yet — those gates print a note and skip, never guess.
Run with --promote to copy the current recorded timings into the
BASELINE files (then commit them) after an intentional perf change.

Usage: check_bench.py [dir-containing-the-BENCH-files] [--promote]
"""

import json
import pathlib
import sys

args = [a for a in sys.argv[1:] if a != "--promote"]
promote = "--promote" in sys.argv[1:]
root = pathlib.Path(args[0] if args else ".")
failures = []


def load(name):
    path = root / name
    if not path.exists():
        failures.append(f"{name}: missing (did the bench smoke step run?)")
        return None
    try:
        return json.loads(path.read_text())
    except ValueError as e:
        failures.append(f"{name}: unparseable ({e})")
        return None


def recorded(doc, name, key):
    value = doc.get(key)
    if value is None:
        failures.append(f"{name}: '{key}' was not recorded (bench did not run?)")
    return value


def ratio_gate(name, doc, fast_key, slow_key, tolerance=1.0, why=""):
    """Require doc[fast_key] < doc[slow_key] * tolerance.

    Both values must have been recorded (null timings already fail via
    recorded()); the gate itself only fires when both are numbers, so
    a single missing field produces one clear failure, not two.
    """
    fast = recorded(doc, name, fast_key)
    slow = recorded(doc, name, slow_key)
    if fast is None or slow is None:
        return
    if fast >= slow * tolerance:
        bound = f"{slow_key} * {tolerance}" if tolerance != 1.0 else slow_key
        failures.append(
            f"{name}: {fast_key} {fast:.3f} ms >= {bound} "
            f"({slow * tolerance:.3f} ms){' — ' + why if why else ''}"
        )


def trend_gate(name, doc):
    """Fail when a recorded timing regresses past the committed baseline.

    The BASELINE file pins which keys are trend-tracked and at what
    tolerance; a null committed value means nobody has promoted a
    baseline yet, which skips (with a note) rather than inventing one.
    Returns (tracked, null) key counts so the caller can tell whether
    ANY trend gate actually armed across the whole run.
    """
    tracked = nulls = 0
    base_name = name.replace("BENCH_", "BASELINE_")
    path = root / base_name
    if not path.exists():
        print(f"note: {base_name} missing; trend gates skipped for {name}")
        return tracked, nulls
    try:
        base = json.loads(path.read_text())
    except ValueError as e:
        failures.append(f"{base_name}: unparseable ({e})")
        return tracked, nulls
    tolerance = base.get("trend_tolerance", 1.5)
    for key, committed in base.get("timings_ms", {}).items():
        tracked += 1
        if committed is None:
            nulls += 1
            print(f"note: {base_name}: '{key}' has no committed baseline yet")
            continue
        value = doc.get(key)
        if value is None:
            failures.append(
                f"{name}: '{key}' was not recorded but {base_name} commits "
                "a baseline for it"
            )
            continue
        if value > committed * tolerance:
            failures.append(
                f"{name}: {key} {value:.3f} ms > {tolerance}x the committed "
                f"baseline {committed:.3f} ms (see {base_name}; promote a new "
                "baseline only for an intentional change)"
            )
    return tracked, nulls


def promote_baseline(name, doc):
    """--promote: copy this run's timings into the BASELINE file."""
    base_name = name.replace("BENCH_", "BASELINE_")
    path = root / base_name
    if not path.exists():
        print(f"note: {base_name} missing; nothing to promote for {name}")
        return
    base = json.loads(path.read_text())
    for key in base.get("timings_ms", {}):
        if doc.get(key) is not None:
            base["timings_ms"][key] = doc[key]
    path.write_text(json.dumps(base, indent=2) + "\n")
    print(f"promoted {name} timings into {base_name}")


sweep = load("BENCH_sweep.json")
if sweep is not None:
    acc = sweep.get("acceptance", {})
    speedup = recorded(sweep, "BENCH_sweep.json", "parallel_speedup")
    floor = acc.get("parallel_speedup_min")
    if speedup is not None and floor is not None and speedup < floor:
        failures.append(
            f"BENCH_sweep.json: parallel_speedup {speedup:.2f} < required {floor}"
        )
    solves = recorded(sweep, "BENCH_sweep.json", "warm_rerun_circuit_solves")
    ceiling = acc.get("warm_rerun_circuit_solves_max", 0)
    if solves is not None and solves > ceiling:
        failures.append(
            "BENCH_sweep.json: warm_rerun_circuit_solves "
            f"{solves} > allowed {ceiling}"
        )
    # the cross-node sweep (16/7/5 nm) must also replay warm with zero
    # circuit solves: per-node CircuitKeys, no 16 nm aliasing
    node_solves = recorded(
        sweep, "BENCH_sweep.json", "node_sweep_warm_rerun_circuit_solves"
    )
    node_ceiling = acc.get("node_sweep_warm_rerun_circuit_solves_max", 0)
    if node_solves is not None and node_solves > node_ceiling:
        failures.append(
            "BENCH_sweep.json: node_sweep_warm_rerun_circuit_solves "
            f"{node_solves} > allowed {node_ceiling}"
        )
    nodes = recorded(sweep, "BENCH_sweep.json", "node_sweep_nodes")
    if nodes is not None and nodes < 3:
        failures.append(
            f"BENCH_sweep.json: node_sweep_nodes {nodes} < 3 "
            "(the bench must cover 16/7/5 nm)"
        )
    # batch axis: traffic-coefficient builds are bounded by the number
    # of (dnn, phase) pairs, NEVER by the batch count — the closed-form
    # BatchLine engine's contract
    traffic_evals = recorded(
        sweep, "BENCH_sweep.json", "batch_sweep_traffic_evals"
    )
    traffic_ceiling = acc.get("batch_sweep_traffic_evals_max")
    if (
        traffic_evals is not None
        and traffic_ceiling is not None
        and traffic_evals > traffic_ceiling
    ):
        failures.append(
            "BENCH_sweep.json: batch_sweep_traffic_evals "
            f"{traffic_evals} > allowed {traffic_ceiling} "
            "(one traffic build per (dnn, phase))"
        )
    warm_traffic = recorded(
        sweep, "BENCH_sweep.json", "batch_sweep_warm_rerun_traffic_evals"
    )
    warm_traffic_ceiling = acc.get("batch_sweep_warm_rerun_traffic_evals_max", 0)
    if warm_traffic is not None and warm_traffic > warm_traffic_ceiling:
        failures.append(
            "BENCH_sweep.json: batch_sweep_warm_rerun_traffic_evals "
            f"{warm_traffic} > allowed {warm_traffic_ceiling}"
        )
    batches = recorded(sweep, "BENCH_sweep.json", "batch_sweep_batches")
    if batches is not None and batches < 16:
        failures.append(
            f"BENCH_sweep.json: batch_sweep_batches {batches} < 16 "
            "(the batch sweep must be wide enough to prove the axis is free)"
        )
    # hybrid tech axis: a way-partitioned selection composes its PPA
    # from the two cached pure partner solves — the sweep must record
    # ZERO circuit solves beyond the pure partners, cold and warm alike
    hybrid_extra = recorded(
        sweep, "BENCH_sweep.json", "hybrid_sweep_extra_circuit_solves"
    )
    hybrid_extra_ceiling = acc.get("hybrid_sweep_extra_circuit_solves_max", 0)
    if hybrid_extra is not None and hybrid_extra > hybrid_extra_ceiling:
        failures.append(
            "BENCH_sweep.json: hybrid_sweep_extra_circuit_solves "
            f"{hybrid_extra} > allowed {hybrid_extra_ceiling} "
            "(hybrids must compose from cached pure solves)"
        )
    hybrid_warm = recorded(
        sweep, "BENCH_sweep.json", "hybrid_sweep_warm_rerun_circuit_solves"
    )
    hybrid_warm_ceiling = acc.get("hybrid_sweep_warm_rerun_circuit_solves_max", 0)
    if hybrid_warm is not None and hybrid_warm > hybrid_warm_ceiling:
        failures.append(
            "BENCH_sweep.json: hybrid_sweep_warm_rerun_circuit_solves "
            f"{hybrid_warm} > allowed {hybrid_warm_ceiling}"
        )
    hybrid_sels = recorded(
        sweep, "BENCH_sweep.json", "hybrid_sweep_tech_selections"
    )
    if hybrid_sels is not None and hybrid_sels < 10:
        failures.append(
            f"BENCH_sweep.json: hybrid_sweep_tech_selections {hybrid_sels} "
            "< 10 (the hybrid sweep must span a real way/steer grid)"
        )
    # /optimize search: branch-and-bound must prune at least
    # optimize_prune_ratio_min grid points per point evaluated (the
    # whole reason the search beats the sweep)
    opt_evaluated = recorded(sweep, "BENCH_sweep.json", "optimize_points_evaluated")
    opt_pruned = recorded(sweep, "BENCH_sweep.json", "optimize_points_pruned")
    opt_floor = acc.get("optimize_prune_ratio_min")
    if opt_evaluated is not None and opt_pruned is not None and opt_floor is not None:
        opt_ratio = opt_pruned / max(opt_evaluated, 1)
        if opt_ratio < opt_floor:
            failures.append(
                "BENCH_sweep.json: optimize pruned/evaluated ratio "
                f"{opt_ratio:.1f} < required {opt_floor} "
                f"({opt_pruned} pruned vs {opt_evaluated} evaluated)"
            )
    recorded(sweep, "BENCH_sweep.json", "optimize_ms")
    # Timing fields are now sourced from the obs histograms; they must
    # be recorded (non-null) and the memoized paths must actually win.
    recorded(sweep, "BENCH_sweep.json", "parallel_ms")
    ratio_gate(
        "BENCH_sweep.json", sweep, "warm_ms", "serial_ms",
        why="a warm rerun must beat the cold serial sweep",
    )
    ratio_gate(
        "BENCH_sweep.json", sweep, "node_sweep_warm_ms", "node_sweep_cold_ms",
        why="the warm node sweep must beat its cold run",
    )
    ratio_gate(
        "BENCH_sweep.json", sweep, "batch_sweep_warm_ms", "batch_sweep_cold_ms",
        why="the warm batch sweep must beat its cold run",
    )
    ratio_gate(
        "BENCH_sweep.json", sweep, "hybrid_sweep_warm_ms", "hybrid_sweep_cold_ms",
        why="the warm hybrid sweep must beat its cold run",
    )

serve = load("BENCH_serve.json")
if serve is not None:
    cold = recorded(serve, "BENCH_serve.json", "cold_solve_ms")
    warm = recorded(serve, "BENCH_serve.json", "warm_solve_ms")
    if cold is not None and warm is not None and warm >= cold:
        failures.append(
            f"BENCH_serve.json: warm_solve_ms {warm:.3f} >= cold_solve_ms "
            f"{cold:.3f} (the memo hit must beat the cold solve)"
        )
    # Keep-alive reuses one pooled connection; it must not lose to the
    # connect-per-request path (tolerance absorbs scheduler noise on
    # sub-millisecond loopback calls).
    ratio_gate(
        "BENCH_serve.json", serve, "warm_solve_keepalive_ms", "warm_solve_ms",
        tolerance=1.25,
        why="pooled keep-alive calls must not be slower than one-shot",
    )

dist = load("BENCH_distributed.json")
if dist is not None:
    acc = dist.get("acceptance", {})
    for key, cap_key in (
        ("replay_solves", "replay_solves_max"),
        ("replay_evals", "replay_evals_max"),
    ):
        value = recorded(dist, "BENCH_distributed.json", key)
        ceiling = acc.get(cap_key, 0)
        if value is not None and value > ceiling:
            failures.append(
                f"BENCH_distributed.json: {key} {value} > allowed {ceiling} "
                "(the merged shard union must cover the full grid)"
            )
    recorded(dist, "BENCH_distributed.json", "single_ms")
    recorded(dist, "BENCH_distributed.json", "distributed_ms")
    retries = recorded(dist, "BENCH_distributed.json", "dispatch_retries")
    if retries is not None and retries > 0:
        failures.append(
            f"BENCH_distributed.json: dispatch_retries {retries} > 0 "
            "(loopback workers must not shed shards)"
        )

trend_tracked = trend_nulls = 0
for name, doc in (
    ("BENCH_sweep.json", sweep),
    ("BENCH_serve.json", serve),
    ("BENCH_distributed.json", dist),
):
    if doc is None:
        continue
    if promote:
        promote_baseline(name, doc)
    else:
        tracked, nulls = trend_gate(name, doc)
        trend_tracked += tracked
        trend_nulls += nulls

if not promote and trend_tracked > 0 and trend_nulls == trend_tracked:
    # Every trend-tracked key is still null: not a failure (the gates
    # are documented to skip-with-a-note until someone promotes), but
    # it must never scroll past silently — an all-null run means the
    # trend gates have NEVER fired and the perf trajectory is entirely
    # unguarded.
    banner = (
        f"WARNING: all {trend_tracked} trend-tracked baseline keys are "
        "null — no trend gate is armed"
    )
    print("=" * len(banner))
    print(banner)
    print(
        "  Every BASELINE_*.json timings_ms entry is still null, so the\n"
        "  regression trend gates above all skipped. Run a real CI bench\n"
        "  pass with --promote and commit the updated BASELINE files to\n"
        "  arm them."
    )
    print("=" * len(banner))
    # surface the same text as a GitHub Actions warning annotation so it
    # shows on the run summary, not just in the step log
    print(f"::warning file=.github/scripts/check_bench.py::{banner}")

if failures:
    print("bench acceptance FAILED:")
    for f in failures:
        print(f"  - {f}")
    sys.exit(1)
print("bench acceptance OK")
