#!/usr/bin/env python3
"""Gate CI on the `deepnvm validate --json` cross-validation report.

The report replays (dnn, phase, capacity) cells through both the
analytic traffic model and the trace-driven hierarchy simulation and
records per-cell relative DRAM-transaction error. This gate fails when:

- the report is missing, unparseable, or carries no cells;
- any cell's rel_err exceeds the bound the report itself carries
  (deepnvm::gpusim::validate::MAX_REL_ERR — the binary already exits
  nonzero on a breach, but re-checking the artifact keeps the gate
  honest even if the exit-code plumbing regresses);
- either substrate recorded zero DRAM transactions anywhere (a cell
  that moved no data validated nothing).

Usage: check_validate.py <validate.json>
"""

import json
import pathlib
import sys

failures = []

if len(sys.argv) != 2:
    print("usage: check_validate.py <validate.json>", file=sys.stderr)
    sys.exit(2)

path = pathlib.Path(sys.argv[1])
if not path.exists():
    print(f"{path}: missing (did `deepnvm validate --json` run?)", file=sys.stderr)
    sys.exit(1)
try:
    doc = json.loads(path.read_text())
except ValueError as e:
    print(f"{path}: unparseable ({e})", file=sys.stderr)
    sys.exit(1)

cells = doc.get("cells", [])
bound = doc.get("bound")
if not cells:
    failures.append("report carries no cells")
if bound is None:
    failures.append("report carries no bound")

for c in cells:
    tag = f"{c.get('dnn')}/{c.get('phase')}/{c.get('capacity_mb')}MB"
    if not c.get("analytic_dram"):
        failures.append(f"{tag}: analytic_dram is zero or missing")
    if not c.get("sim_dram"):
        failures.append(f"{tag}: sim_dram is zero or missing")
    rel = c.get("rel_err")
    if rel is None:
        failures.append(f"{tag}: rel_err missing")
    elif bound is not None and rel > bound:
        failures.append(f"{tag}: rel_err {rel:.4f} > bound {bound}")

if doc.get("pass") is not True:
    failures.append(f"report did not self-report pass (max_rel_err "
                    f"{doc.get('max_rel_err')}, bound {bound})")

if failures:
    print("validate acceptance FAILED:")
    for f in failures:
        print(f"  - {f}")
    sys.exit(1)

worst = max((c.get("rel_err", 0.0) for c in cells), default=0.0)
print(f"validate acceptance OK: {len(cells)} cell(s), "
      f"max rel_err {worst:.4f} <= bound {bound}")
