#!/usr/bin/env python3
"""Assert a stitched fleet trace proves cross-process correlation.

Reads the Chrome trace JSON `deepnvm coordinate --trace-out` writes and
fails unless:
  - the document carries a nonempty traceId;
  - at least two distinct worker processes (pid >= 2) contributed
    `http./shard/run` spans tagged with the coordinator's trace id;
  - every such worker span names a coordinator `shard.dispatch` span
    (pid 1, same trace id) as its remoteParent;
  - flow-link events (`shard.dispatch.flow`, ph "s" and "f") connect
    dispatches to worker spans.

Usage: check_fleet_trace.py <trace.json> [min-worker-pids]
"""

import json
import sys

path = sys.argv[1]
min_workers = int(sys.argv[2]) if len(sys.argv) > 2 else 2
failures = []

with open(path) as f:
    doc = json.load(f)

trace_id = doc.get("traceId")
if not isinstance(trace_id, str) or not trace_id:
    failures.append(f"traceId missing or empty: {trace_id!r}")

events = doc.get("traceEvents", [])
if not events:
    failures.append("traceEvents is empty")


def args(e):
    a = e.get("args")
    return a if isinstance(a, dict) else {}


dispatch_ids = {
    args(e).get("id")
    for e in events
    if e.get("name") == "shard.dispatch"
    and e.get("pid") == 1
    and args(e).get("trace") == trace_id
}
if not dispatch_ids:
    failures.append("no coordinator shard.dispatch spans on the trace id")

shard_runs = [
    e
    for e in events
    if e.get("name") == "http./shard/run"
    and e.get("pid", 0) >= 2
    and args(e).get("trace") == trace_id
]
worker_pids = sorted({e["pid"] for e in shard_runs})
if len(worker_pids) < min_workers:
    failures.append(
        f"only {len(worker_pids)} worker pid(s) {worker_pids} carry "
        f"shard.run spans on trace {trace_id} (need >= {min_workers})"
    )

orphans = [
    e for e in shard_runs if args(e).get("remoteParent") not in dispatch_ids
]
if orphans:
    failures.append(
        f"{len(orphans)} worker shard.run span(s) have a remoteParent "
        "that is not a coordinator dispatch span"
    )

flow_phases = {
    e.get("ph") for e in events if e.get("name") == "shard.dispatch.flow"
}
for ph in ("s", "f"):
    if ph not in flow_phases:
        failures.append(f"no flow event with ph={ph!r} links the processes")

if failures:
    print("fleet trace check FAILED:")
    for f in failures:
        print(f"  - {f}")
    sys.exit(1)
print(
    f"fleet trace OK: {len(shard_runs)} worker shard.run span(s) across "
    f"pids {worker_pids} correlated to {len(dispatch_ids)} dispatch span(s) "
    f"on trace {trace_id}"
)
